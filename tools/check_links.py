"""Markdown link checker for the docs site (stdlib only).

Walks the given markdown files (default: README.md + docs/**.md),
extracts inline links and images, and fails if a *relative* link points
at a file that does not exist, or a ``#fragment`` names a heading the
target markdown file does not define.  External (http/https/mailto)
links are counted but not fetched — CI must not flake on someone else's
server.

    python tools/check_links.py [FILES...]
"""
from __future__ import annotations

import glob
import os
import re
import sys

# inline links/images: [text](target) — code spans stripped first so
# `foo(bar)` examples don't parse as links
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_CODE_SPAN_RE = re.compile(r"`[^`]*`")
_FENCE_RE = re.compile(r"^(```|~~~)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def _anchor(heading: str) -> str:
    """GitHub's heading -> fragment rule: lowercase, drop punctuation,
    spaces to dashes."""
    h = _CODE_SPAN_RE.sub(lambda m: m.group(0).strip("`"), heading.strip())
    h = re.sub(r"[^\w\- ]", "", h.lower())
    return h.replace(" ", "-")


def _parse(path: str):
    """Yield (lineno, target) links; collect the file's own anchors."""
    links, anchors = [], set()
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if _FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING_RE.match(line)
            if m:
                anchors.add(_anchor(m.group(1)))
            for lm in _LINK_RE.finditer(_CODE_SPAN_RE.sub("", line)):
                links.append((lineno, lm.group(1)))
    return links, anchors


def check(files: list[str]) -> int:
    parsed = {os.path.abspath(p): _parse(p) for p in files}
    errors, external, internal = [], 0, 0
    for path, (links, _) in parsed.items():
        base = os.path.dirname(path)
        for lineno, target in links:
            if target.startswith(("http://", "https://", "mailto:")):
                external += 1
                continue
            internal += 1
            dest, _, frag = target.partition("#")
            dest_path = os.path.abspath(os.path.join(base, dest)) \
                if dest else path
            rel = os.path.relpath(path)
            if not os.path.exists(dest_path):
                errors.append(f"{rel}:{lineno}: broken link -> {target}")
                continue
            if frag and dest_path.endswith(".md"):
                if dest_path not in parsed:
                    parsed[dest_path] = _parse(dest_path)
                if _anchor(frag) not in parsed[dest_path][1]:
                    errors.append(
                        f"{rel}:{lineno}: missing anchor -> {target}")
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: {internal} internal links ok, "
          f"{external} external skipped, {len(errors)} broken")
    return 1 if errors else 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = argv or (
        [os.path.join(root, "README.md")]
        + sorted(glob.glob(os.path.join(root, "docs", "**", "*.md"),
                           recursive=True)))
    missing = [f for f in files if not os.path.exists(f)]
    if missing:
        print(f"no such file(s): {missing}", file=sys.stderr)
        return 2
    return check(files)


if __name__ == "__main__":
    sys.exit(main())
