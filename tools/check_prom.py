#!/usr/bin/env python3
"""Validate Prometheus text exposition format 0.0.4 — stdlib only.

The serve/ingest tiers render their metrics registries as Prometheus
text (``GET /metrics?format=prom``); this validator is what CI (and
``tests/test_obs.py``) holds that output against, without needing a
prometheus client library in the image:

* every sample line parses as ``name[{labels}] value`` with a legal
  metric name, legal label syntax, and a float-parseable value;
* every sample's base name is covered by a preceding ``# TYPE``
  declaration, and no name is declared twice with different types;
* histogram series are structurally complete and consistent: the
  ``_bucket`` samples of each label set are cumulative (non-decreasing
  with ``le``), end at ``le="+Inf"``, and agree with the ``_count``
  sample; ``_sum``/``_count`` exist for every bucket family;
* counters never carry a negative value.

Usage::

    python tools/check_prom.py FILE        # or '-' for stdin
    python tools/check_prom.py http://127.0.0.1:8422/metrics?format=prom

Exits 0 and prints a one-line summary when valid; exits 1 with every
violation otherwise.
"""
from __future__ import annotations

import re
import sys

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>\S+)(?:\s+(?P<ts>-?\d+))?$")
_LABEL_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')
_TYPES = ("counter", "gauge", "histogram", "summary", "untyped")
_HIST_SUFFIXES = ("_bucket", "_sum", "_count")


def _base_name(name: str, types: dict) -> str:
    """The TYPE-declared family a sample belongs to (histogram samples
    carry _bucket/_sum/_count suffixes; counters carry _total)."""
    for suffix in _HIST_SUFFIXES:
        if name.endswith(suffix) and types.get(name[:-len(suffix)]) in (
                "histogram", "summary"):
            return name[:-len(suffix)]
    return name


def _parse_labels(raw: str, errors: list, lineno: int) -> dict:
    labels: dict[str, str] = {}
    if not raw:
        return labels
    for part in raw.split(","):
        part = part.strip()
        if not _LABEL_RE.match(part):
            errors.append(f"line {lineno}: bad label pair {part!r}")
            continue
        k, v = part.split("=", 1)
        labels[k] = v[1:-1]
    return labels


def check_exposition(text: str) -> tuple[list[str], dict]:
    """Validate exposition text.  Returns ``(errors, stats)``; valid
    input yields an empty error list."""
    errors: list[str] = []
    types: dict[str, str] = {}
    samples = 0
    # histogram family -> label-set(frozen, minus le) -> [(le, value)]
    buckets: dict[str, dict[frozenset, list[tuple[float, float]]]] = {}
    counts: dict[str, dict[frozenset, float]] = {}

    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in _TYPES:
                errors.append(f"line {lineno}: malformed TYPE: {line!r}")
                continue
            name, mtype = parts[2], parts[3]
            if not _NAME_RE.match(name):
                errors.append(f"line {lineno}: bad metric name {name!r}")
            if name in types and types[name] != mtype:
                errors.append(f"line {lineno}: {name} redeclared as {mtype} "
                              f"(was {types[name]})")
            types[name] = mtype
            continue
        if line.startswith("#"):
            continue  # HELP / comments
        m = _SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {lineno}: unparseable sample: {line!r}")
            continue
        name, raw_labels = m.group("name"), m.group("labels")
        try:
            value = float(m.group("value"))
        except ValueError:
            errors.append(f"line {lineno}: non-numeric value "
                          f"{m.group('value')!r}")
            continue
        labels = _parse_labels(raw_labels or "", errors, lineno)
        samples += 1
        base = _base_name(name, types)
        mtype = types.get(base)
        if mtype is None:
            errors.append(f"line {lineno}: sample {name} has no TYPE "
                          f"declaration")
            continue
        if mtype == "counter" and value < 0:
            errors.append(f"line {lineno}: counter {name} is negative")
        if mtype == "histogram":
            key = frozenset((k, v) for k, v in labels.items() if k != "le")
            if name.endswith("_bucket"):
                le_raw = labels.get("le")
                if le_raw is None:
                    errors.append(f"line {lineno}: bucket sample without "
                                  f"an le label")
                    continue
                le = float("inf") if le_raw == "+Inf" else float(le_raw)
                buckets.setdefault(base, {}).setdefault(key, []).append(
                    (le, value))
            elif name.endswith("_count"):
                counts.setdefault(base, {})[key] = value

    for base, by_labels in buckets.items():
        for key, series in by_labels.items():
            label_str = dict(sorted(key)) if key else ""
            les = [le for le, _ in series]
            vals = [v for _, v in series]
            if les != sorted(les):
                errors.append(f"{base}{label_str}: le edges out of order")
            if vals != sorted(vals):
                errors.append(f"{base}{label_str}: bucket counts not "
                              f"cumulative")
            if not les or les[-1] != float("inf"):
                errors.append(f"{base}{label_str}: missing le=\"+Inf\" "
                              f"bucket")
            total = counts.get(base, {}).get(key)
            if total is None:
                errors.append(f"{base}{label_str}: missing _count sample")
            elif les and les[-1] == float("inf") and vals[-1] != total:
                errors.append(f"{base}{label_str}: +Inf bucket {vals[-1]} "
                              f"!= _count {total}")

    stats = {"samples": samples, "families": len(types),
             "histograms": sum(1 for t in types.values()
                               if t == "histogram")}
    return errors, stats


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 1:
        print(__doc__.strip().splitlines()[0], file=sys.stderr)
        print("usage: check_prom.py <file|-|url>", file=sys.stderr)
        return 2
    src = argv[0]
    if src == "-":
        text = sys.stdin.read()
    elif src.startswith(("http://", "https://")):
        from urllib.request import urlopen
        with urlopen(src, timeout=30) as resp:
            text = resp.read().decode("utf-8")
    else:
        with open(src, encoding="utf-8") as f:
            text = f.read()
    errors, stats = check_exposition(text)
    for e in errors:
        print(f"INVALID {e}", file=sys.stderr)
    if errors:
        print(f"check_prom: {len(errors)} violation(s) in {stats['samples']} "
              f"samples", file=sys.stderr)
        return 1
    print(f"check_prom: ok — {stats['samples']} samples, "
          f"{stats['families']} families, "
          f"{stats['histograms']} histograms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
