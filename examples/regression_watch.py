"""Continuous diagnosis end to end: baseline fleet -> epoch stream ->
regression watch -> findings over HTTP.

Builds a three-run baseline fleet of a tiny synthetic app, then streams
live epochs into a snapshot root (each epoch is one complete run's
snapshot).  A :class:`RegressionWatch` follows the root and evaluates
every published epoch against the baselines' per-path noise bands:

* epoch 1 reruns the app unchanged — run-to-run jitter stays inside the
  bands, zero findings;
* epoch 2 injects a 6x slowdown in ``fn_halo_exchange`` on two of the
  eight ranks — the watch flags the regression *by call path* within one
  poll interval, and the load-imbalance analyzer independently flags the
  same context (two ranks now dwarf the other six).

The same snapshot root is then served by a multi-tenant
:class:`QueryHTTPServer` (``prod`` = the live root, ``staging`` = a
clean control root), and the findings are fetched through the typed
client's ``GET /v1/findings`` — ``prod`` shows the imbalance, ``staging``
stays clean.

    PYTHONPATH=src python examples/regression_watch.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.analysis.report import findings_table
from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.core.cct import KIND_MODULE, KIND_OP, KIND_PHASE, ContextTree
from repro.core.sparse import MeasurementProfile, SparseMetrics, Trace
from repro.diagnose import RegressionWatch, WatchTarget
from repro.ingest import IngestState, SnapshotStore
from repro.serve import QueryClient, QueryHTTPServer

N_RANKS = 8
FUNCTIONS = {"fn_halo_exchange": 3.0, "fn_stencil": 5.0,
             "fn_reduce": 1.0, "fn_io": 0.5}


def make_fleet(run_seed, *, slow_ranks=(), factor=1.0):
    """One run: eight structurally identical rank profiles with ~1%
    run-to-run jitter, optionally slowing fn_halo_exchange on a subset."""
    rng = np.random.default_rng(run_seed)
    profs = []
    for rank in range(N_RANKS):
        tree = ContextTree()
        main = tree.child(0, KIND_PHASE, "main")
        solver = tree.child(main, KIND_MODULE, "solver")
        fns = {name: tree.child(solver, KIND_OP, name) for name in FUNCTIONS}
        ctxs, mids, vals = [], [], []
        for name, cost in FUNCTIONS.items():
            v = cost * (1.0 + 0.01 * rng.standard_normal())
            if name == "fn_halo_exchange" and rank in slow_ranks:
                v *= factor
            ctxs.append(fns[name])
            mids.append(0)
            vals.append(v)
        trace = Trace(np.sort(rng.uniform(0.0, 1.0, 40)),
                      rng.choice(np.asarray(list(fns.values())),
                                 40).astype(np.uint32))
        profs.append(MeasurementProfile(
            environment={"app": "halo-demo"}, identity={"rank": rank},
            file_paths=[], tree=tree, trace=trace, metrics=
            SparseMetrics.from_triplets(ctxs, mids, vals)))
    return profs


def build_run(out_dir, profs, cfg):
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for i, prof in enumerate(profs):
        p = os.path.join(out_dir, f"rank{i:02d}.rprf")
        prof.save(p)
        paths.append(p)
    StreamingAggregator(out_dir, cfg).run(paths)
    return paths


def publish_epoch(store, profs, scratch, cfg):
    """One complete run as the next snapshot epoch."""
    state = IngestState(config=cfg)
    paths = []
    for i, prof in enumerate(profs):
        p = os.path.join(scratch, f"e{time.monotonic_ns()}_{i}.rprf")
        prof.save(p)
        paths.append(p)
    state.append(paths)
    epoch, _ = store.publish(state.write_database)
    return epoch


def main():
    cfg = AggregationConfig(executor="serial")
    with tempfile.TemporaryDirectory() as td:
        baselines = os.path.join(td, "baselines")
        for j in range(3):
            build_run(os.path.join(baselines, f"run{j}"),
                      make_fleet(run_seed=j), cfg)
        print(f"baseline fleet: 3 runs x {N_RANKS} ranks under {baselines}")

        prod_root = os.path.join(td, "prod")
        stage_root = os.path.join(td, "staging")
        os.makedirs(prod_root), os.makedirs(stage_root)
        scratch = os.path.join(td, "scratch")
        os.makedirs(scratch)
        prod, stage = SnapshotStore(prod_root), SnapshotStore(stage_root)
        publish_epoch(stage, make_fleet(run_seed=40), scratch, cfg)
        e1 = publish_epoch(prod, make_fleet(run_seed=41), scratch, cfg)

        reports = []
        with RegressionWatch(
                WatchTarget(name="prod", root=prod_root, baseline=baselines,
                            metric=0, inclusive=False,
                            analyzers=("imbalance", "straggler")),
                poll_ms=50.0, on_report=reports.append) as watch:
            assert reports[0].findings == (), "clean epoch must stay clean"
            print(f"epoch {e1}: evaluated on start, zero findings "
                  f"(jitter stays inside the noise bands)")

            # the regression ships: 6x fn_halo_exchange on ranks 0-1
            e2 = publish_epoch(
                prod, make_fleet(run_seed=42, slow_ranks=(0, 1), factor=6.0),
                scratch, cfg)
            deadline = time.monotonic() + 10.0
            while len(reports) < 2:
                if time.monotonic() > deadline:
                    raise SystemExit("watch never saw the new epoch")
                time.sleep(0.02)
            rep = reports[1]
            assert rep.epoch == e2 and rep.worst == "critical", rep.as_dict()
            flagged = {f.kind for f in rep.findings}
            assert "regression" in flagged and "load_imbalance" in flagged
            assert any("fn_halo_exchange" in (f.path or "")
                       for f in rep.findings)
            print(f"epoch {e2}: flagged in {rep.eval_s*1e3:.1f} ms\n")
            print(findings_table(rep.findings) + "\n")
            st = watch.status()
            print(f"watch counters: {st['counters']}")

        # serve both roots behind one front; findings over HTTP per tenant
        with QueryHTTPServer(tenants={"prod": prod_root,
                                      "staging": stage_root},
                             follow=True, poll_ms=25.0, port=0) as srv:
            host, port = srv.address
            with QueryClient(host, port, tenant="prod") as pc, \
                    QueryClient(host, port, tenant="staging") as sc:
                hot = pc.findings(metric=0)
                assert any(f.kind == "load_imbalance" for f in hot)
                assert sc.findings(metric=0) == []
                print(f"\nGET /v1/findings tenant=prod -> {len(hot)} "
                      f"finding(s); tenant=staging -> 0")
                print(f"  worst: {hot[0].message}")
    print("regression_watch OK")


if __name__ == "__main__":
    main()
