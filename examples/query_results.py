"""Postmortem querying end to end: top-k, threshold select, two-run diff.

Builds two analysis databases from synthetic measurement runs (the second
a simulated regression: every metric 1.6x the first), then answers the
paper's browser-shaped questions through ``repro.query`` — no dense
matrices, no hand-rolled reader loops.

    PYTHONPATH=src python examples/query_results.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import generate_timing_workload
from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.core.metrics import INCLUSIVE_BIT
from repro.core.sparse import MeasurementProfile
from repro.query import (Database, diff, occupancy, threshold_contexts,
                         topk_hot_paths, total_delta)


def main():
    with tempfile.TemporaryDirectory() as td:
        # ---- run A, and run B = run A with a 1.6x cost regression ----
        paths_a, _, _ = generate_timing_workload(td + "/in_a", n_profiles=24,
                                                 n_private=100)
        paths_b = []
        for p in paths_a:
            prof = MeasurementProfile.load(p)
            prof.metrics.val = prof.metrics.val * 1.6  # loaded arrays are RO
            q = td + "/in_b/" + os.path.basename(p)
            os.makedirs(td + "/in_b", exist_ok=True)
            prof.save(q)
            paths_b.append(q)
        cfg = AggregationConfig(executor="threads", n_workers=4)
        StreamingAggregator(td + "/db_a", cfg).run(paths_a)
        StreamingAggregator(td + "/db_b", cfg).run(paths_b)

        with Database(td + "/db_a") as db_a, Database(td + "/db_b") as db_b:
            metric = int(db_a.stats["mid"][0]) & ~INCLUSIVE_BIT

            print("== top-5 hot paths by inclusive cost (summary stats only)")
            for hp in topk_hot_paths(db_a, metric, k=5):
                print(f"  {hp.value:12.3f} (excl {hp.exclusive:10.3f})  "
                      f"{hp.path}")

            print("\n== contexts over threshold (cross-profile sum >= 5.0)")
            ctxs, vals = threshold_contexts(db_a, metric, min_value=5.0,
                                            inclusive=True)
            for c, v in list(zip(ctxs, vals))[:5]:
                print(f"  ctx {int(c):5d}  {v:10.3f}  {db_a.path_of(int(c))}")
            print(f"  ... {len(ctxs)} contexts total")

            print("\n== run B vs run A (simulated regression)")
            ta, tb = total_delta(db_a, db_b, metric)
            print(f"  exclusive totals: A={ta:.1f}  B={tb:.1f}  "
                  f"({tb / ta:.2f}x)")
            for e in diff(db_a, db_b, metric, top=5):
                print(f"  {e.delta:+12.3f}  ({e.a:10.3f} -> {e.b:10.3f})  "
                      f"{e.path}")

            print("\n== trace occupancy, window [10s, 20s)")
            ctx, counts = occupancy(db_a, 10.0, 20.0)
            order = (-counts).argsort()[:5]
            for i in order:
                print(f"  {int(counts[i]):6d} samples  "
                      f"{db_a.path_of(int(ctx[i]))}")

            # the engine's routing discipline, observable:
            print(f"\ncounters: {db_a.counters}  cache: "
                  f"{db_a.cache_stats()}")
            assert db_a.counters["pms_plane_loads"] == 0  # never scanned PMS
    print("query_results OK")


if __name__ == "__main__":
    main()
