"""End-to-end driver: train a ~100M-param model for a few hundred steps
with checkpointing + per-worker profiling, then run the streaming
aggregation over the collected profiles and print the analysis summary.

This is the paper's full workflow at container scale: measurement
(sparse per-worker profiles) -> post-mortem streaming aggregation ->
PMS/CMS databases a browser would read.

    PYTHONPATH=src python examples/train_profiled.py [--steps 300]
"""
import argparse
import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.core.cms import CMSReader
from repro.core.pms import PMSReader
from repro.data import TokenPipeline
from repro.models import params as PD
from repro.models.api import build_model
from repro.profiling import Profiler
from repro.train.loop import Trainer, TrainerConfig, make_train_step
from repro.train.optimizer import AdamWConfig

# ~100M params: 12L x 512d x 8H, 32k vocab
CFG_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
    dtype="float32", remat=False, q_chunk=64, kv_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--out", default="runs/train_profiled")
    # NOTE: the full 300-step default is sized for real hardware; on this
    # CPU container use e.g. --steps 60 --batch 4 --seq 64 (validated).
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    model = build_model(CFG_100M)
    n = PD.count(model.param_defs())
    print(f"model: {n/1e6:.1f}M params")
    pipe = TokenPipeline(CFG_100M.vocab_size, seq_len=args.seq, global_batch=args.batch)
    ckpt = CheckpointManager(os.path.join(args.out, "ckpt"), keep=2)
    # two simulated workers: a host-metric worker and a device-stream worker
    profs = [Profiler({"rank": 0, "stream": 0, "kind": "host"}),
             Profiler({"rank": 0, "stream": 1, "kind": "device"})]
    tr = Trainer(model, AdamWConfig(lr=3e-4, warmup_steps=20),
                 TrainerConfig(steps=args.steps, ckpt_every=100),
                 pipe, ckpt=ckpt, profiler=profs[0])
    params, opt = tr.init_state()

    compiled = jax.jit(make_train_step(model, AdamWConfig())).lower(
        params, opt, {"tokens": jnp.asarray(pipe.batch_at(0))}).compile()
    ca = compiled.cost_analysis() or {}
    profs[1].attribute_compiled(
        compiled.as_text(), measured={"flops": ca.get("flops", 0.0)},
        struct_dir=os.path.join(args.out, "structs"))

    params, opt = tr.run(params, opt, steps=args.steps)
    print(f"loss: {tr.history[0]['loss']:.3f} -> {tr.history[-1]['loss']:.3f} "
          f"over {args.steps} steps")
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]

    paths = []
    for i, p in enumerate(profs):
        path = os.path.join(args.out, f"worker{i}.rprf")
        p.finish(path)
        paths.append(path)
    res = StreamingAggregator(os.path.join(args.out, "db"),
                              AggregationConfig(n_threads=2)).run(paths)
    print(f"analysis: {res.n_contexts} unified contexts, "
          f"{res.n_values} values")
    print(f"sizes: {res.sizes}")
    with PMSReader(res.pms_path) as r, CMSReader(res.cms_path) as c:
        reg = {m["name"]: m["mid"] for m in r.meta["registry"]}
        # top-5 device contexts by HBM bytes across profiles (CMS stripe)
        stats = r.stats
        mask = stats["mid"] == reg.get("dev.bytes_hbm", -1)
        order = stats["sum"][mask].argsort()[::-1][:5]
        ctxs = stats["ctx"][mask][order]
        print("top device contexts by bytes:")
        for ctx in ctxs:
            print(f"  {r.tree.full_path(int(ctx))[:90]}")
    print("train_profiled OK")


if __name__ == "__main__":
    main()
