"""Quickstart: train a reduced model, profile it, analyze the profiles.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import get_arch, reduced
from repro.core.aggregate import StreamingAggregator
from repro.core.pms import PMSReader
from repro.data import TokenPipeline
from repro.models.api import build_model
from repro.profiling import Profiler
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import AdamWConfig


def main():
    cfg = reduced(get_arch("qwen3-0.6b"))
    model = build_model(cfg)
    pipe = TokenPipeline(cfg.vocab_size, seq_len=64, global_batch=8)
    prof = Profiler({"rank": 0, "stream": 0, "kind": "host"})
    tr = Trainer(model, AdamWConfig(lr=1e-3, warmup_steps=5),
                 TrainerConfig(steps=20), pipe, profiler=prof)
    params, opt = tr.init_state()
    params, opt = tr.run(params, opt, steps=20)
    print(f"loss: {tr.history[0]['loss']:.3f} -> {tr.history[-1]['loss']:.3f}")

    with tempfile.TemporaryDirectory() as td:
        ppath = os.path.join(td, "w0.rprf")
        prof.finish(ppath)
        res = StreamingAggregator(os.path.join(td, "db")).run([ppath])
        with PMSReader(res.pms_path) as r:
            reg = {m["name"]: m["mid"] for m in r.meta["registry"]}
            plane = r.plane(0)
            from repro.core.metrics import INCLUSIVE_BIT
            total = plane.lookup(0, reg["host.step_time"] | INCLUSIVE_BIT)
            print(f"analysis DB: {res.n_contexts} contexts, "
                  f"{res.n_values} values, PMS {res.sizes['pms']} B")
            print(f"total step time from inclusive rollup: {total:.3f}s")
    assert tr.history[-1]["loss"] < tr.history[0]["loss"]
    print("quickstart OK")


if __name__ == "__main__":
    main()
