"""Query service over HTTP, end to end against a fixture database.

Builds a small analysis database, starts the :class:`QueryHTTPServer`
(warming the plane cache from summary statistics first), then talks to it
through the typed :class:`QueryClient` the way an analysis dashboard
would: health check, a batched dashboard call, single-op conveniences,
and a look at the /metrics counters.

This serves one static database directory.  The same server can instead
*follow* a live snapshot root (``QueryHTTPServer(root, follow=True)``),
reopening on every published epoch — ``examples/ingest_stream.py`` runs
that variant end to end against the ingest tier.

    PYTHONPATH=src python examples/serve_http.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import generate_timing_workload
from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.query import Database
from repro.serve import QueryClient, QueryHTTPServer, QueryRequest


def main():
    with tempfile.TemporaryDirectory() as td:
        paths, _, _ = generate_timing_workload(td + "/in", n_profiles=16,
                                               n_private=80)
        StreamingAggregator(
            td + "/db", AggregationConfig(executor="threads", n_workers=4)
        ).run(paths)

        with Database(td + "/db", cache_bytes=32 << 20) as db, \
                QueryHTTPServer(db, port=0, max_batch=16, max_queue=256,
                                warm_bytes=None) as srv:  # None = default budget
            host, port = srv.address
            print(f"serving {db.n_profiles} profiles / {db.n_contexts} "
                  f"contexts at {srv.url}")
            print(f"warm start: {srv.warm_report}")

            with QueryClient(host, port) as cl:
                print(f"health: {cl.health()}")

                print("\n== top-5 hot paths over HTTP")
                for hp in cl.topk(0, k=5):
                    print(f"  {hp.value:12.3f}  {hp.path}")

                print("\n== a dashboard call: one POST, many queries")
                ctx = int(db.stats["ctx"][0])
                mid = int(db.stats["mid"][0])
                results = cl.batch([
                    QueryRequest(op="profile", pid=0),
                    QueryRequest(op="stripe", ctx=ctx, metric=mid),
                    QueryRequest(op="value", pid=1, ctx=ctx, metric=mid),
                    QueryRequest(op="window", pid=0, t0=0.0, t1=30.0),
                ])
                sm, (prof, vals), v, win = results
                print(f"  profile 0: {sm.n_values} values")
                print(f"  stripe(ctx={ctx}, m={mid}): {prof.size} profiles")
                print(f"  value(pid=1): {v:.3f}")
                print(f"  window[0,30): {win.time.size} samples")

                m = cl.metrics()
                print(f"\ncache: {m['cache']}")
                print(f"scheduler: completed={m['scheduler']['completed']} "
                      f"batches={m['scheduler']['batches']} "
                      f"mean_batch={m['scheduler']['mean_batch_size']:.2f}")
    print("serve_http OK")


if __name__ == "__main__":
    main()
