"""The live pipeline end to end: stream uploads -> snapshot epochs ->
a follower that serves each epoch without restart.

Starts the :class:`IngestHTTPServer` over an empty snapshot root,
uploads profiles over HTTP in three increments (publishing after each),
and points a ``follow=True`` :class:`QueryHTTPServer` at the same root:
the query side picks up every published epoch live, and the final
snapshot is byte-identical to a one-shot batch aggregation of the same
profiles — the incremental write path re-cuts the phase boundary, it
never changes the bytes.

    PYTHONPATH=src python examples/ingest_stream.py
"""
import filecmp
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import generate_timing_workload
from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.ingest import IngestClient, IngestHTTPServer, epoch_dirname
from repro.serve import QueryClient, QueryHTTPServer, QueryRequest


def main():
    with tempfile.TemporaryDirectory() as td:
        paths, _, _ = generate_timing_workload(td + "/in", n_profiles=12,
                                               n_private=60)
        cfg = AggregationConfig(executor="serial")
        root = td + "/live"

        with IngestHTTPServer(root, port=0, config=cfg) as ingest, \
                IngestClient(*ingest.address) as ic:
            # first increment + publish gives the follower an epoch to open
            print(f"ingest at {ingest.url}")
            ic.upload_files(paths[:4])
            print(f"published epoch {ic.publish()['epoch']}")

            with QueryHTTPServer(root, follow=True, poll_ms=25.0,
                                 port=0) as srv, \
                    QueryClient(*srv.address) as qc:
                print(f"follower at {srv.url} on epoch "
                      f"{qc.health()['epoch']}")

                # stream the rest in two more increments; the follower
                # crosses each epoch transition without restart
                for lo, hi in ((4, 8), (8, 12)):
                    ic.upload_with_retry([open(p, "rb").read()
                                          for p in paths[lo:hi]])
                    epoch = ic.publish()["epoch"]
                    deadline = time.monotonic() + 10.0
                    while qc.health()["epoch"] != epoch:
                        if time.monotonic() > deadline:
                            raise SystemExit("follower never caught up")
                        time.sleep(0.05)
                    rows = qc.topk(0, k=3)
                    print(f"epoch {epoch}: {srv.db.n_profiles} profiles, "
                          f"top value {rows[0].value:.3f}")
                    results = qc.batch([
                        QueryRequest(op="profile", pid=0),
                        QueryRequest(op="threshold", metric=0,
                                     params={"min_value": 0.0})])
                    print(f"  batch: plane of {results[0].n_values} values, "
                          f"{results[1][0].size} contexts over threshold")

                em = qc.metrics()["epoch"]
                assert em["transitions"] >= 3 and em["follow_errors"] == 0, em
                final = qc.health()["epoch"]

        # parity: the streamed final epoch == one-shot batch aggregation
        StreamingAggregator(td + "/oneshot", cfg).run(paths)
        for name in ("db.pms", "db.cms", "db.trc"):
            a = os.path.join(root, epoch_dirname(final), name)
            b = os.path.join(td + "/oneshot", name)
            assert filecmp.cmp(a, b, shallow=False), f"{name} diverged"
        print("final epoch byte-identical to one-shot analyze")
    print("ingest_stream OK")


if __name__ == "__main__":
    main()
