"""Post-mortem analysis example: the paper's workflow on a synthetic
exascale-shaped measurement set — streaming aggregation vs the dense
baseline, single-rank threads vs the MPI-analog multiprocess driver.

    PYTHONPATH=src python examples/analyze_postmortem.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import generate_timing_workload
from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.core.cms import CMSReader
from repro.core.dense_baseline import DenseAnalysis
from repro.core.reduction import aggregate_multiprocess


def main():
    with tempfile.TemporaryDirectory() as td:
        paths, n_ctx, n_metrics = generate_timing_workload(
            td + "/in", n_profiles=64)
        meas = sum(os.path.getsize(p) for p in paths)
        print(f"{len(paths)} profiles, {meas/2**20:.1f} MiB measurements")

        t0 = time.perf_counter()
        DenseAnalysis(td + "/dense.npy").run(paths)
        t_dense = time.perf_counter() - t0
        dense_bytes = os.path.getsize(td + "/dense.npy")

        t0 = time.perf_counter()
        res = StreamingAggregator(td + "/db",
                                  AggregationConfig(n_threads=4)).run(paths)
        t_stream = time.perf_counter() - t0
        sparse_bytes = res.sizes["pms"] + res.sizes["cms"]

        t0 = time.perf_counter()
        aggregate_multiprocess(paths, td + "/db_mp", n_ranks=2,
                               threads_per_rank=2)
        t_mp = time.perf_counter() - t0

        print(f"dense (HPCToolkit-style, 1t): {t_dense:.2f}s, "
              f"{dense_bytes/2**20:.1f} MiB results")
        print(f"streaming aggregation (4t):   {t_stream:.2f}s, "
              f"{sparse_bytes/2**20:.1f} MiB results "
              f"-> {t_dense/t_stream:.1f}x faster, "
              f"{dense_bytes/sparse_bytes:.0f}x smaller")
        print(f"2 ranks x 2 threads (MPI analog): {t_mp:.2f}s")

        # interactive-browser access pattern: one stripe read serves
        # "metric m for context c across ALL profiles" (paper §3.2)
        with CMSReader(res.cms_path) as c:
            for ctx in range(0, res.n_contexts, max(res.n_contexts // 3, 1)):
                prof_ids, vals = c.stripe(ctx, 2)
                if len(prof_ids):
                    print(f"ctx {ctx}: metric 2 on {len(prof_ids)} profiles, "
                          f"mean {vals.mean():.3f}")
    print("analyze_postmortem OK")


if __name__ == "__main__":
    main()
