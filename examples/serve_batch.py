"""Batched serving example: coalesced requests through the ServeEngine.

    PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.configs.base import get_arch, reduced
from repro.models import params as PD
from repro.models.api import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced(get_arch("yi-6b"))
    model = build_model(cfg)
    params = PD.init_params(model.param_defs(), 0, jnp.float32)
    eng = ServeEngine(model, params, max_len=48, max_batch=4)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, 16).astype(np.int32), 8)
            for _ in range(10)]
    t0 = time.perf_counter()
    outs = eng.serve(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    print(f"{len(reqs)} requests -> {toks} tokens in {dt:.2f}s")
    # determinism: same prompt -> same continuation
    a = eng.serve([reqs[0]])[0]
    np.testing.assert_array_equal(a, outs[0])
    print("serve_batch OK")


if __name__ == "__main__":
    main()
