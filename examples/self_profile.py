"""The profiler profiles itself: serve under load, export spans, analyze.

Round trip of the self-hosted observability stack (``repro.obs``):

1. build a fixture database and serve it over HTTP with a **sharded**
   backend (2 worker processes) and the flight recorder on;
2. drive a batch of traced dashboard calls through ``QueryClient`` —
   trace ids minted at the edge ride through the scheduler, across the
   shm/pickle transport into shard workers, and come back with the
   workers' spans piggybacked on replies;
3. scrape ``/metrics?format=prom`` (validated with tools/check_prom.py)
   and ``/debug/spans``;
4. export the recorder's ring through :mod:`repro.obs.export` into the
   repo's own trace-plane format, and analyze the server's execution
   with the *same* query ops it was just serving: ``topk`` over
   ``obs.time`` ranks the serve phases, ``samples_in_window`` /
   ``occupancy`` lay the fleet's spans on one timeline.

    PYTHONPATH=src python examples/self_profile.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import generate_timing_workload
from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.obs import configure, mint_trace_id
from repro.obs.export import export_spans
from repro.query import Database, occupancy, samples_in_window, topk_hot_paths
from repro.serve import QueryClient, QueryHTTPServer, QueryRequest

from tools.check_prom import check_exposition


def main():
    with tempfile.TemporaryDirectory() as td:
        paths, _, _ = generate_timing_workload(td + "/in", n_profiles=12,
                                               n_private=60)
        StreamingAggregator(
            td + "/db", AggregationConfig(executor="threads", n_workers=4)
        ).run(paths)

        with Database(td + "/db", cache_bytes=32 << 20) as db, \
                QueryHTTPServer(db, port=0, shards=2, warm_bytes=0,
                                trace_ring=4096) as srv:
            host, port = srv.address
            print(f"serving {db.n_profiles} profiles at {srv.url} "
                  f"(2 shard workers, trace ring on)")

            ctx = int(db.stats["ctx"][0])
            mid = int(db.stats["mid"][0])
            tid = mint_trace_id()
            with QueryClient(host, port) as cl:
                for _ in range(20):
                    cl.batch([
                        QueryRequest(op="profile", pid=0),
                        QueryRequest(op="stripe", ctx=ctx, metric=mid),
                        QueryRequest(op="value", pid=1, ctx=ctx, metric=mid),
                        QueryRequest(op="topk", metric=0, inclusive=True,
                                     k=5),
                    ], trace_id=tid)
                assert cl.last_trace_id == tid, "server must echo our id"

                print("\n== GET /metrics?format=prom")
                import http.client
                conn = http.client.HTTPConnection(host, port, timeout=30)
                conn.request("GET", "/metrics?format=prom")
                text = conn.getresponse().read().decode("utf-8")
                conn.close()
                errors, stats = check_exposition(text)
                assert not errors, errors
                print(f"  valid exposition: {stats['samples']} samples, "
                      f"{stats['families']} families")

                spans_body = cl._roundtrip("GET", "/debug/spans?limit=64")
                print(f"== GET /debug/spans: {spans_body['recorded']} "
                      f"recorded, showing {spans_body['n']}")
                shards_seen = {s["shard"] for s in spans_body["spans"]}
                assert any(sh >= 0 for sh in shards_seen), \
                    "no worker spans shipped back"

            # freeze the ring before stop() tears the fleet down
            from repro.obs import recorder
            spans = recorder().snapshot()
            traced = sum(1 for s in spans if s.trace_id == tid)
            print(f"\n{len(spans)} spans in the ring, {traced} carrying "
                  f"our trace id {tid}")
            assert traced > 0

        summary = export_spans(spans, td + "/obs")
        print(f"\n== exported to our own trace-plane format: {summary}")

        # ... and analyze the server's own execution with the standard ops
        with Database(summary["db_dir"]) as obs_db:
            print("\n== top-5 serve phases by time (topk over obs.time)")
            for hp in topk_hot_paths(obs_db, "obs.time", k=5):
                print(f"  {hp.value * 1e3:10.3f} ms  {hp.path}")

            t1 = summary["t_span_s"] + 1.0
            win = samples_in_window(obs_db, 0, 0.0, t1)
            ctx_ids, counts = occupancy(obs_db, 0.0, t1)
            print(f"\n== timeline: profile 0 has {win.time.size} span "
                  f"samples; occupancy covers {ctx_ids.size} contexts "
                  f"/ {int(counts.sum())} samples")
            assert win.time.size > 0 and counts.sum() > 0

    configure(0)
    print("\nself_profile OK")


if __name__ == "__main__":
    main()
