"""Logical-axis sharding rules (DP / TP / EP / SP / FSDP / pod).

Model code annotates tensors with *logical* axis names; a
:class:`ShardingRules` table maps logical names to mesh axes.  Changing the
parallelism strategy (the §Perf hillclimb lever) means swapping rule
tables, never touching model code.

Mesh axes (see ``repro.launch.mesh``):

* ``data`` — data parallel (batch), and the FSDP/ZeRO shard axis
* ``model`` — tensor parallel (heads / ff / vocab / experts)
* ``pod``  — second-level data parallel across pods (hierarchical DP);
             optionally an extra FSDP axis for the largest models
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (str | tuple | None)."""

    rules: dict = field(default_factory=dict)
    mesh_axis_sizes: dict = field(default_factory=dict)

    def axis(self, name: str):
        return self.rules.get(name)

    def size(self, name: str) -> int:
        ax = self.rules.get(name)
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            s = 1
            for a in ax:
                s *= self.mesh_axis_sizes.get(a, 1)
            return s
        return self.mesh_axis_sizes.get(ax, 1)

    def with_overrides(self, **kv) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kv)
        return replace(self, rules=d)


def train_rules(mesh_axis_sizes: dict, *, fsdp: bool = False,
                pod_in_batch: bool = True, seq_shard: bool = False) -> ShardingRules:
    """Default DP+TP rules; ``fsdp`` adds ZeRO-3 param sharding over data;
    ``seq_shard`` puts sequence over `model` between blocks (SP)."""
    batch_axes = ("pod", "data") if (pod_in_batch and "pod" in mesh_axis_sizes) else ("data",)
    return ShardingRules(rules={
        "batch": batch_axes if len(batch_axes) > 1 else batch_axes[0],
        "tokens": batch_axes if len(batch_axes) > 1 else batch_axes[0],
        "seq": "model" if seq_shard else None,
        "kv_seq": None,
        "embed": None,           # activation d_model: replicated
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "expert_ff": None,
        "moe_cap": None,
        "layers": None,
        # FSDP/ZeRO shards params over ALL batch axes (data, and pod when
        # present) — a 314B model only fits when both axes participate
        "fsdp": (batch_axes if len(batch_axes) > 1 else batch_axes[0]) if fsdp else None,
        "ssm_inner": "model",
        "ssm_state": None,
        "conv_k": None,
    }, mesh_axis_sizes=dict(mesh_axis_sizes))


def decode_rules(mesh_axis_sizes: dict, *, kv_seq_shard: bool = False,
                 fsdp: bool = False) -> ShardingRules:
    """Decode/serving rules: batch over data; long-context KV over data (SP).

    With ``kv_seq_shard`` (batch too small for the data axis, e.g.
    long_500k's batch=1) the *sequence* of the KV cache takes the data
    axis and batch/tokens go unsharded.
    """
    r = train_rules(mesh_axis_sizes, fsdp=fsdp, pod_in_batch=True)
    if kv_seq_shard:
        return r.with_overrides(kv_seq="data", seq=None, batch=None,
                                tokens=None)
    return r.with_overrides(kv_seq=None, seq=None)


# -- thread-local active (mesh, rules) ---------------------------------------

class _Ctx(threading.local):
    mesh = None
    rules: ShardingRules | None = None


_ctx = _Ctx()


@contextlib.contextmanager
def set_rules(mesh, rules: ShardingRules):
    old = (_ctx.mesh, _ctx.rules)
    _ctx.mesh, _ctx.rules = mesh, rules
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = old


def current_rules() -> ShardingRules | None:
    return _ctx.rules


def logical_to_spec(logical_axes: tuple, rules: ShardingRules | None = None) -> P:
    rules = rules or _ctx.rules
    if rules is None:
        return P()
    parts = []
    used: set = set()

    def _take(ax):
        # a mesh axis may appear at most once in a PartitionSpec
        if ax is None:
            return None
        if isinstance(ax, tuple):
            ax2 = tuple(a for a in ax if a not in used)
            used.update(ax2)
            return ax2 if ax2 else None
        if ax in used:
            return None
        used.add(ax)
        return ax

    for name in logical_axes:
        if name is None:
            parts.append(None)
            continue
        if isinstance(name, tuple):  # compound: first mappable wins, e.g. ("fsdp","ff")
            axes = tuple(a for a in (_take(rules.axis(n)) for n in name) if a)
            flat = tuple(x for a in axes for x in ((a,) if isinstance(a, str) else a))
            parts.append(flat if flat else None)
            continue
        parts.append(_take(rules.axis(name)))
    return P(*parts)


def spec_for(logical_axes: tuple, rules: ShardingRules | None = None):
    """NamedSharding for the active mesh (None outside a mesh context)."""
    rules = rules or _ctx.rules
    mesh = _ctx.mesh
    if mesh is None or rules is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules))


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    s = spec_for(tuple(logical_axes))
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
