from repro.sharding.specs import (ShardingRules, constrain, current_rules,
                                  logical_to_spec, set_rules, spec_for)

__all__ = ["ShardingRules", "constrain", "current_rules", "logical_to_spec",
           "set_rules", "spec_for"]
