"""Mixture-of-Experts block: top-k routing, sort-based capacity dispatch.

TPU-shaped dispatch (no dynamic shapes): tokens are routed with
``lax.top_k``, positions within each expert come from a sort + exclusive
scan (the same histogram/scan/scatter idiom as the aggregation kernels),
and tokens beyond ``capacity = N/E * cf * k`` are dropped (Switch-style).

Sharding: the dispatched (E, C, D) buffer is constrained to the
``experts`` logical axis.  With experts on the `model` mesh axis (EP —
qwen3-moe, 128 % 16 == 0) the token gather/scatter across the
data<->experts layout boundary becomes the MoE all-to-all; with experts
replicated and ``expert_ff`` on `model` (grok, 8 experts), experts compute
as tensor-parallel GEMMs instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def moe_block(x, router_w, wg, wu, wd, *, top_k: int, capacity_factor: float,
              act: str = "silu"):
    """x (B, S, D); router_w (D, E); wg/wu (E, D, F); wd (E, F, D)."""
    B, S, D = x.shape
    E = router_w.shape[-1]
    N = B * S
    K = top_k
    C = max(int(N * K * capacity_factor / E + 0.5), 8)
    C = min(-(-C // 32) * 32, max(N, 32))  # 32-aligned: capacity dim shards

    xf = constrain(x.reshape(N, D), "tokens", "embed")
    logits = jnp.einsum("nd,de->ne", xf, router_w,
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, "tokens", None)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                 # (N, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                              # (N*K,)
    flat_t = jnp.repeat(jnp.arange(N), K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position of each routed copy within its expert: rank - expert start
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(N * K) - starts[sorted_e]
    pos = jnp.zeros(N * K, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))

    keep = pos < C
    safe_pos = jnp.minimum(pos, C - 1)

    # dispatch: (E, C, D) expert buffers (EP all-to-all boundary)
    tok = jnp.take(xf, flat_t, axis=0)                     # (N*K, D)
    tok = constrain(jnp.where(keep[:, None], tok, 0), "tokens", "embed")
    # capacity dim sharded over the data axes: per-chip buffers stay
    # O(C/data) instead of a fully-replicated (E, C, D) tensor
    buf = jnp.zeros((E, C, D), x.dtype).at[flat_e, safe_pos].add(tok)
    buf = constrain(buf, "experts", "moe_cap", "embed")

    f = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = f(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum("ecd,edf->ecf", buf, wu)
    h = constrain(h, "experts", "moe_cap", "expert_ff")
    y = jnp.einsum("ecf,efd->ecd", h, wd)
    y = constrain(y, "experts", "moe_cap", "embed")

    # combine: gather each routed copy back and weight by its gate
    y_tok = y[flat_e, safe_pos]                            # (N*K, D)
    y_tok = constrain(jnp.where(keep[:, None], y_tok, 0), "tokens", "embed")
    w = gates.reshape(-1)[:, None].astype(y_tok.dtype)
    out = jax.ops.segment_sum(y_tok * w, flat_t, num_segments=N)
    out = constrain(out, "tokens", "embed")
    return out.reshape(B, S, D).astype(x.dtype), probs


def moe_aux_loss(probs: jax.Array, eidx_unused=None) -> jax.Array:
    """Load-balancing auxiliary loss (mean prob * fraction routed proxy)."""
    me = probs.mean(axis=0)
    return probs.shape[-1] * jnp.sum(me * me)


def moe_block_rowwise(x, router_w, wg, wu, wd, *, top_k: int,
                      capacity_factor: float, act: str = "silu",
                      pos_chunk: int = 2048):
    """Row-local dispatch (§Perf hillclimb — the beyond-baseline MoE path).

    The sorted dispatch routes through a *global* argsort + scatter whose
    GSPMD lowering is collective-heavy (measured ~46 s/step of all-reduce
    for qwen3-moe).  A first rewrite that scattered tokens directly into
    an experts-sharded (B, E, C, D) buffer was REFUTED: GSPMD replicates
    scatters onto sharded dims (all-reduce grew to ~412 s).  This version
    never scatters activations across the expert sharding:

    * positions within (row, expert) come from a chunked running-count
      cumsum — no global sort;
    * a tiny (B, E*C) int32 slot->token index map is scattered instead of
      activations (KBs, replication-safe);
    * dispatch is then a *gather* from the data-sharded token array —
      gathers shard by output, so each (data, model) chip fills only its
      own (B_loc, E_loc, C, D) buffer locally;
    * combine scatter-adds each chip's expert outputs back into token
      space and lets one (B, S, D) psum over `model` finish the job —
      the same cost shape as a Megatron row-parallel matmul.
    """
    B, S, D = x.shape
    E = router_w.shape[-1]
    K = top_k
    T = S * K
    C = max(int(T * capacity_factor / E + 0.5), 8)
    C = min(-(-C // 8) * 8, T)

    logits = jnp.einsum("bsd,de->bse", x, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                   # (B, S, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(B, T)
    gates_flat = gates.reshape(B, T)
    # positions via chunked running counts (B, E) — one local pass, no sort
    nck = -(-T // pos_chunk)
    pad = nck * pos_chunk - T
    fe = jnp.pad(flat_e, ((0, 0), (0, pad)), constant_values=E)
    fe_c = jnp.moveaxis(fe.reshape(B, nck, pos_chunk), 1, 0)

    def body(counts, e_chunk):
        oh = jax.nn.one_hot(e_chunk, E, dtype=jnp.int32)    # (B, ck, E)
        run = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]
        pos = jnp.take_along_axis(
            run, jnp.minimum(e_chunk, E - 1)[..., None], axis=-1)[..., 0]
        return counts + oh.sum(axis=1), pos

    _, pos_chunks = jax.lax.scan(body, jnp.zeros((B, E), jnp.int32), fe_c)
    pos = jnp.moveaxis(pos_chunks, 0, 1).reshape(B, -1)[:, :T]
    keep = pos < C
    safe_pos = jnp.minimum(pos, C - 1)

    # slot->copy map: the ONLY scatter, and it is (B, E*C+1) int32
    bidx_t = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T))
    slot = jnp.where(keep, flat_e * C + safe_pos, E * C)
    slot_src = jnp.full((B, E * C + 1), T, jnp.int32)
    slot_src = slot_src.at[bidx_t, slot].set(
        jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T)))
    slot_src = slot_src[:, : E * C]                          # (B, E*C)

    # dispatch = gather (shard-local: output sharding rules the gather)
    src_tok = jnp.where(slot_src < T, slot_src // K, S)      # sentinel -> pad row
    xf_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    buf = jnp.take_along_axis(xf_pad, src_tok[..., None], axis=1)
    buf = buf.reshape(B, E, C, D)
    buf = constrain(buf, "batch", "experts", None, "embed")

    f = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = f(jnp.einsum("becd,edf->becf", buf, wg)) \
        * jnp.einsum("becd,edf->becf", buf, wu)
    h = constrain(h, "batch", "experts", None, "expert_ff")
    y = jnp.einsum("becf,efd->becd", h, wd)
    y = constrain(y, "batch", "experts", None, "embed")

    # combine: weight each slot by its copy gate, scatter-add into tokens
    slot_gate = jnp.where(
        slot_src < T,
        jnp.take_along_axis(gates_flat, jnp.minimum(slot_src, T - 1), axis=1),
        0.0).astype(y.dtype)                                  # (B, E*C)
    contrib = y.reshape(B, E * C, D) * slot_gate[..., None]
    bidx_s = jnp.broadcast_to(jnp.arange(B)[:, None], (B, E * C))
    out_pad = jnp.zeros((B, S + 1, D), y.dtype).at[bidx_s, src_tok].add(contrib)
    out = constrain(out_pad[:, :S], "batch", "seq", "embed")
    return out.astype(x.dtype), probs.reshape(-1, E)
