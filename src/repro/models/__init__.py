from repro.models.api import build_model

__all__ = ["build_model"]
