"""SSM-family models: Mamba2 (SSD), xLSTM (mLSTM + sLSTM), Zamba2 hybrid.

All recurrences share one chunked linear-RNN core (the SSD duality): state
``H_t = a_t * H_{t-1} + v_t (x) k_t``, readout ``y_t = H_t . q_t``, computed
chunk-parallel — intra-chunk quadratic attention-like einsums + inter-chunk
state carry under ``lax.scan`` — so training cost is linear in sequence
length and the 500k-token decode shapes carry history in O(1) state.

Decode steps reuse the same math with a length-1 chunk, so
prefill-then-decode exactly matches a full forward pass (tested).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import chunked_softmax_xent, rms_norm
from repro.models.lm import block as attn_block
from repro.models.params import ParamDef
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# the chunked linear-RNN core (Mamba2 SSD form / gated linear attention)
# ---------------------------------------------------------------------------

def linear_rnn_chunked(log_a, v, k, q, h0, *, chunk: int):
    """Chunk-parallel linear RNN.

    log_a (B, S, H) f32 per-head log decay (<= 0);
    v (B, S, H, P) values; k/q (B, S, Hk, N) with Hk in {1, H};
    h0 (B, H, P, N) entering state.  Returns (y (B, S, H, P), h_out).
    """
    B, S, H, P = v.shape
    N = k.shape[-1]
    Hk = k.shape[2]
    c = min(chunk, S)
    nc = -(-S // c)
    pad = nc * c - S
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))

    la = jnp.moveaxis(log_a.reshape(B, nc, c, H), 1, 0)          # (nc,B,c,H)
    vs = jnp.moveaxis(v.reshape(B, nc, c, H, P), 1, 0).astype(jnp.float32)
    ks = jnp.moveaxis(k.reshape(B, nc, c, Hk, N), 1, 0).astype(jnp.float32)
    qs = jnp.moveaxis(q.reshape(B, nc, c, Hk, N), 1, 0).astype(jnp.float32)

    shared_kq = Hk == 1  # Mamba2: B/C shared across heads; mLSTM: per-head

    def body(h, inp):
        lac, vc, kc, qc = inp
        cum = jnp.cumsum(lac, axis=1)                            # (B,c,H)
        # (B, H, j, i) decay matrix with causal mask i <= j
        dj = cum.transpose(0, 2, 1)                               # (B,H,c)
        dmat = dj[:, :, :, None] - dj[:, :, None, :]              # (B,H,j,i)
        mask = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(mask[None, None], jnp.exp(dmat), 0.0)
        eg = jnp.exp(cum)                                          # (B,c,H)
        tot = cum[:, -1, :]                                        # (B,H)
        rem = jnp.exp(tot[:, None, :] - cum)                       # (B,c,H)
        if shared_kq:
            kcs, qcs = kc[:, :, 0], qc[:, :, 0]                    # (B,c,N)
            qk = jnp.einsum("bjn,bin->bji", qcs, kcs,
                            preferred_element_type=jnp.float32)
            A = qk[:, None] * w                                    # (B,H,j,i)
            y_inter = jnp.einsum("bhpn,bjn,bjh->bjhp", h, qcs, eg,
                                 preferred_element_type=jnp.float32)
            h_upd = jnp.einsum("bihp,bin,bih->bhpn", vc, kcs, rem,
                               preferred_element_type=jnp.float32)
        else:
            qk = jnp.einsum("bjhn,bihn->bhji", qc, kc,
                            preferred_element_type=jnp.float32)
            A = qk * w
            y_inter = jnp.einsum("bhpn,bjhn,bjh->bjhp", h, qc, eg,
                                 preferred_element_type=jnp.float32)
            h_upd = jnp.einsum("bihp,bihn,bih->bhpn", vc, kc, rem,
                               preferred_element_type=jnp.float32)
        y_intra = jnp.einsum("bhji,bihp->bjhp", A, vc,
                             preferred_element_type=jnp.float32)
        h_new = h * jnp.exp(tot)[:, :, None, None] + h_upd
        return h_new, y_intra + y_inter

    # checkpoint per chunk: AD would otherwise stack the (B, H, c, c)
    # intra-chunk decay/attention matrices for every chunk; with the
    # checkpoint only the (B, H, P, N) chunk-entry states are saved.
    h_out, ys = jax.lax.scan(jax.checkpoint(body), h0.astype(jnp.float32),
                             (la, vs, ks, qs))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * c, H, P)[:, :S]
    return y, h_out


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_defs(cfg: ModelConfig, L: int) -> dict:
    D, DI, N, H, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.n_ssm_heads, cfg.ssm_conv)
    proj_out = 2 * DI + 2 * N + H
    return {
        "ln": ParamDef((L, D), ("layers", None), "zeros"),
        "in_proj": ParamDef((L, D, proj_out), ("layers", "fsdp", "ssm_inner")),
        "conv_w": ParamDef((L, K, DI), ("layers", "conv_k", "ssm_inner")),
        "conv_b": ParamDef((L, DI), ("layers", "ssm_inner"), "zeros"),
        "A_log": ParamDef((L, H), ("layers", None), "zeros"),
        "D_skip": ParamDef((L, H), ("layers", None), "ones"),
        "dt_bias": ParamDef((L, H), ("layers", None), "zeros"),
        "norm": ParamDef((L, DI), ("layers", "ssm_inner"), "zeros"),
        "out_proj": ParamDef((L, DI, D), ("layers", "ssm_inner", "fsdp")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv; x (B, S, DI), w (K, DI).  ``state`` is the
    last K-1 inputs for decode; returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return y + b, new_state


def mamba2_block(p, x, cfg: ModelConfig, state=None):
    """Returns (x + out, new_state).  state = (h (B,H,P,N), conv (B,K-1,DI))."""
    B, S, D = x.shape
    DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = DI // H
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xs, Bv, Cv, dt = jnp.split(
        zxbcdt, [DI, 2 * DI, 2 * DI + N, 2 * DI + 2 * N], axis=-1)
    conv_state = None if state is None else state["conv"]
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)
    xs = constrain(xs, "batch", "seq", "ssm_inner")
    Bv = jax.nn.silu(Bv).astype(jnp.float32)
    Cv = jax.nn.silu(Cv).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt          # (B,S,H)
    v = xs.reshape(B, S, H, P).astype(jnp.float32) * dt[..., None]
    k = Bv[:, :, None, :]                                          # (B,S,1,N)
    q = Cv[:, :, None, :]
    h0 = (jnp.zeros((B, H, P, N), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))
    y, h_out = linear_rnn_chunked(log_a, v, k, q, h0, chunk=cfg.ssm_chunk)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.reshape(B, S, H, P).astype(jnp.float32)
    y = y.reshape(B, S, DI).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_state = None
    if state is not None:
        new_state = {"h": h_out, "conv": new_conv.astype(state["conv"].dtype)}
    return x + out, new_state


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def mlstm_defs(cfg: ModelConfig, L: int) -> dict:
    D, DI, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    N = DI // H
    return {
        "ln": ParamDef((L, D), ("layers", None), "zeros"),
        "up": ParamDef((L, D, 2 * DI), ("layers", "fsdp", "ssm_inner")),
        "wq": ParamDef((L, DI, DI), ("layers", None, "ssm_inner")),
        "wk": ParamDef((L, DI, DI), ("layers", None, "ssm_inner")),
        "wv": ParamDef((L, DI, DI), ("layers", None, "ssm_inner")),
        "w_if": ParamDef((L, DI, 2 * H), ("layers", "ssm_inner", None)),
        "norm": ParamDef((L, DI), ("layers", "ssm_inner"), "zeros"),
        "down": ParamDef((L, DI, D), ("layers", "ssm_inner", "fsdp")),
    }


def mlstm_block(p, x, cfg: ModelConfig, state=None):
    """mLSTM: matrix memory + normalizer (folded as an extra value channel)."""
    B, S, D = x.shape
    DI, H = cfg.d_inner, cfg.n_heads
    N = DI // H
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xi, z = jnp.split(h @ p["up"], 2, axis=-1)
    q = (xi @ p["wq"]).reshape(B, S, H, N)
    k = (xi @ p["wk"]).reshape(B, S, H, N) / math.sqrt(N)
    v = (xi @ p["wv"]).reshape(B, S, H, N)
    gates = (xi @ p["w_if"]).astype(jnp.float32)
    i_g = jax.nn.sigmoid(gates[..., :H])                            # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., H:])
    # fold normalizer: value channel N+1 carries the input gate itself
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32) * i_g[..., None],
         i_g[..., None] * jnp.ones((B, S, H, 1), jnp.float32)], axis=-1)
    h0 = (jnp.zeros((B, H, N + 1, N), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))
    y_aug, h_out = linear_rnn_chunked(log_f, v_aug, k, q, h0, chunk=cfg.ssm_chunk)
    y = y_aug[..., :N]
    denom = jnp.maximum(jnp.abs(y_aug[..., N]), 1.0)[..., None]
    y = (y / denom).reshape(B, S, DI).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["down"]
    new_state = None if state is None else {"h": h_out}
    return x + out, new_state


def slstm_defs(cfg: ModelConfig, L: int) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    return {
        "ln": ParamDef((L, D), ("layers", None), "zeros"),
        "w_gates": ParamDef((L, D, 4 * D), ("layers", "fsdp", "ssm_inner")),
        "r_gates": ParamDef((L, H, hd, 4 * hd), ("layers", None, None, None)),
        "out": ParamDef((L, D, D), ("layers", "ssm_inner", "fsdp")),
    }


def slstm_block(p, x, cfg: ModelConfig, state=None):
    """sLSTM: per-head scalar memory with recurrent gate contributions.

    Sequential scan over time (cheap per step: (hd x 4hd) per head)."""
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    pre = (h_in @ p["w_gates"]).reshape(B, S, H, 4 * hd).astype(jnp.float32)
    if state is None:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.ones((B, H, hd), jnp.float32)
        hp0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        c0, n0, hp0 = (state["c"].astype(jnp.float32),
                       state["n"].astype(jnp.float32),
                       state["hp"].astype(jnp.float32))
    R = p["r_gates"].astype(jnp.float32)

    def step(carry, pre_t):
        c, n, hp = carry
        rec = jnp.einsum("bhd,hdk->bhk", hp, R)
        g = pre_t + rec                                             # (B,H,4hd)
        i_g, f_g, z_g, o_g = jnp.split(g, 4, axis=-1)
        i_g = jax.nn.sigmoid(i_g)
        f_g = jax.nn.sigmoid(f_g)
        c = f_g * c + i_g * jnp.tanh(z_g)
        n = f_g * n + i_g
        hp = jax.nn.sigmoid(o_g) * c / jnp.maximum(n, 1.0)
        return (c, n, hp), hp

    (c, n, hp), ys = jax.lax.scan(step, (c0, n0, hp0), jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D).astype(x.dtype)
    out = y @ p["out"]
    new_state = None if state is None else {"c": c, "n": n, "hp": hp}
    return x + out, new_state


# ---------------------------------------------------------------------------
# models
# ---------------------------------------------------------------------------

def _take(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _slice(tree, lo, hi):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


class MambaLM:
    """Mamba2 LM; with ``cfg.attn_every`` > 0 it is the Zamba2 hybrid:
    one *shared* attention+MLP transformer block (single parameter set)
    applied before every group of ``attn_every`` Mamba2 layers, each
    application with its own KV cache."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = []
        step = cfg.attn_every or cfg.n_layers
        lo = 0
        while lo < cfg.n_layers:
            self.groups.append((lo, min(lo + step, cfg.n_layers)))
            lo += step

    @property
    def n_attn_apps(self) -> int:
        return len(self.groups) if self.cfg.attn_every else 0

    def param_defs(self):
        cfg = self.cfg
        D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
        defs = {
            "embed": ParamDef((V, D), ("vocab", "fsdp"), "embed"),
            "layers": mamba2_defs(cfg, L),
            "final_norm": ParamDef((D,), (None,), "zeros"),
            "lm_head": ParamDef((D, V), ("fsdp", "vocab")),
        }
        if cfg.attn_every:
            H, KVH, hd, F = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
            defs["shared_attn"] = {
                "ln_attn": ParamDef((D,), (None,), "zeros"),
                "wq": ParamDef((D, H * hd), ("fsdp", "heads")),
                "wk": ParamDef((D, KVH * hd), ("fsdp", "kv_heads")),
                "wv": ParamDef((D, KVH * hd), ("fsdp", "kv_heads")),
                "wo": ParamDef((H * hd, D), ("heads", "fsdp")),
                "ln_mlp": ParamDef((D,), (None,), "zeros"),
                "w_gate": ParamDef((D, F), ("fsdp", "ff")),
                "w_up": ParamDef((D, F), ("fsdp", "ff")),
                "w_down": ParamDef((F, D), ("ff", "fsdp")),
            }
        return defs

    def _zero_states(self, B):
        cfg = self.cfg
        H, P, N = cfg.n_ssm_heads, cfg.d_inner // cfg.n_ssm_heads, cfg.ssm_state
        L, K, DI = cfg.n_layers, cfg.ssm_conv, cfg.d_inner
        return {
            "h": jnp.zeros((L, B, H, P, N), jnp.float32),
            "conv": jnp.zeros((L, B, K - 1, DI), jnp.dtype(cfg.dtype)),
        }

    def _backbone(self, params, x, positions, mode, cache=None, cache_len=None):
        cfg = self.cfg
        states = cache["ssm"] if mode == "decode" else (
            self._zero_states(x.shape[0]) if mode == "prefill" else None)

        def mamba_scan(pslice, x, sslice):
            def body(carry, xs):
                if sslice is not None:
                    p, st = xs
                    xc, new_st = mamba2_block(p, carry, cfg, st)
                    return xc, new_st
                xc, _ = mamba2_block(xs, carry, cfg, None)
                return xc, 0
            fn = body
            if cfg.remat and mode == "train":
                fn = jax.checkpoint(body)
            xs = pslice if sslice is None else (pslice, sslice)
            return jax.lax.scan(fn, x, xs)

        new_states = []
        new_kv = []
        for g, (lo, hi) in enumerate(self.groups):
            if cfg.attn_every:
                kv_arg = None
                if mode == "prefill":
                    kv_arg = "collect"
                elif mode == "decode":
                    kv_arg = (cache["attn_k"][g], cache["attn_v"][g])
                x, kv, _ = attn_block(params["shared_attn"], x, positions, cfg,
                                      kv_arg, cache_len)
                if kv is not None:
                    new_kv.append(kv)
            sl = None if states is None else _slice(states, lo, hi)
            x, st = mamba_scan(_slice(params["layers"], lo, hi), x, sl)
            if states is not None:
                new_states.append(st)
        new_cache = None
        if mode in ("prefill", "decode"):
            ssm = jax.tree_util.tree_map(
                lambda *gs: jnp.concatenate(gs, axis=0), *new_states)
            new_cache = {"ssm": ssm}
            if cfg.attn_every:
                new_cache["attn_k"] = jnp.stack([k for k, _ in new_kv])
                new_cache["attn_v"] = jnp.stack([v for _, v in new_kv])
        return x, new_cache

    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        x = constrain(x, "batch", "seq", "embed")
        positions = jnp.arange(S)[None, :]
        x, _ = self._backbone(params, x, positions, "train")
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
        return chunked_softmax_xent(x, params["lm_head"], labels, mask)

    def prefill(self, params, batch, max_len: int | None = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        positions = jnp.arange(S)[None, :]
        x, cache = self._backbone(params, x, positions, "prefill")
        if cfg.attn_every and max_len is not None and max_len > S:
            pad = ((0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0))
            cache["attn_k"] = jnp.pad(cache["attn_k"], pad)
            cache["attn_v"] = jnp.pad(cache["attn_v"], pad)
        xl = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", xl, params["lm_head"],
                            preferred_element_type=jnp.float32)[:, 0]
        cache["len"] = jnp.full((), S, jnp.int32)
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B = tokens.shape[0]
        clen = cache["len"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        positions = jnp.full((B, 1), clen, jnp.int32)
        x, new_cache = self._backbone(params, x, positions, "decode",
                                      cache=cache, cache_len=clen)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                            preferred_element_type=jnp.float32)[:, 0]
        new_cache["len"] = clen + 1
        return logits, new_cache

    def cache_defs(self, batch_size: int, max_len: int):
        cfg = self.cfg
        H, P, N = cfg.n_ssm_heads, cfg.d_inner // cfg.n_ssm_heads, cfg.ssm_state
        L, K, DI = cfg.n_layers, cfg.ssm_conv, cfg.d_inner
        defs = {
            "ssm": {
                "h": ParamDef((L, batch_size, H, P, N),
                              ("layers", "batch", None, "ssm_inner", "ssm_state"),
                              "zeros"),
                "conv": ParamDef((L, batch_size, K - 1, DI),
                                 ("layers", "batch", None, "ssm_inner"), "zeros"),
            },
            "len": ParamDef((), (), "zeros"),
        }
        if cfg.attn_every:
            A, KVH, hd = self.n_attn_apps, cfg.n_kv_heads, cfg.hd
            kv = ParamDef((A, batch_size, max_len, KVH, hd),
                          (None, "batch", "kv_seq", "kv_heads", "head_dim"),
                          "zeros")
            defs["attn_k"] = kv
            defs["attn_v"] = kv
        return defs


class XLSTMLM:
    """xLSTM: mLSTM blocks with an sLSTM block every ``slstm_every``."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        e = cfg.slstm_every or 0
        self.n_slstm = cfg.n_layers // e if e else 0
        self.n_mlstm = cfg.n_layers - self.n_slstm
        self.per_group = (e - 1) if e else cfg.n_layers

    def param_defs(self):
        cfg = self.cfg
        D, V = cfg.d_model, cfg.vocab_size
        defs = {
            "embed": ParamDef((V, D), ("vocab", "fsdp"), "embed"),
            "mlstm": mlstm_defs(cfg, self.n_mlstm),
            "final_norm": ParamDef((D,), (None,), "zeros"),
            "lm_head": ParamDef((D, V), ("fsdp", "vocab")),
        }
        if self.n_slstm:
            defs["slstm"] = slstm_defs(cfg, self.n_slstm)
        return defs

    def _zero_states(self, B):
        cfg = self.cfg
        DI, H = cfg.d_inner, cfg.n_heads
        N = DI // H
        hd = cfg.d_model // H
        return {
            "m": {"h": jnp.zeros((self.n_mlstm, B, H, N + 1, N), jnp.float32)},
            "s": {"c": jnp.zeros((self.n_slstm, B, H, hd), jnp.float32),
                  "n": jnp.ones((self.n_slstm, B, H, hd), jnp.float32),
                  "hp": jnp.zeros((self.n_slstm, B, H, hd), jnp.float32)},
        }

    def _backbone(self, params, x, mode, cache=None):
        cfg = self.cfg
        states = cache["ssm"] if mode == "decode" else (
            self._zero_states(x.shape[0]) if mode == "prefill" else None)

        def mlstm_scan(pslice, x, sslice):
            def body(carry, xs):
                if sslice is not None:
                    p, st = xs
                    xc, new_st = mlstm_block(p, carry, cfg, st)
                    return xc, new_st
                xc, _ = mlstm_block(xs, carry, cfg, None)
                return xc, 0
            fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
            xs = pslice if sslice is None else (pslice, sslice)
            return jax.lax.scan(fn, x, xs)

        n_groups = max(self.n_slstm, 1)
        new_m, new_s = [], []
        for g in range(n_groups):
            lo, hi = g * self.per_group, (g + 1) * self.per_group
            sl = None if states is None else _slice(states["m"], lo, hi)
            x, st = mlstm_scan(_slice(params["mlstm"], lo, hi), x, sl)
            if states is not None:
                new_m.append(st)
            if self.n_slstm:
                s_st = None if states is None else _take(states["s"], g)
                x, s_new = slstm_block(_take(params["slstm"], g), x, cfg, s_st)
                if states is not None:
                    new_s.append(s_new)
        new_cache = None
        if mode in ("prefill", "decode"):
            m = jax.tree_util.tree_map(lambda *gs: jnp.concatenate(gs, 0), *new_m)
            out = {"m": m}
            if new_s:
                out["s"] = jax.tree_util.tree_map(lambda *gs: jnp.stack(gs, 0), *new_s)
            else:
                out["s"] = states["s"] if states else None
            new_cache = {"ssm": out}
        return x, new_cache

    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        x = constrain(x, "batch", "seq", "embed")
        x, _ = self._backbone(params, x, "train")
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
        return chunked_softmax_xent(x, params["lm_head"], labels, mask)

    def prefill(self, params, batch, max_len: int | None = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        x, cache = self._backbone(params, x, "prefill")
        xl = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", xl, params["lm_head"],
                            preferred_element_type=jnp.float32)[:, 0]
        cache["len"] = jnp.full((), S, jnp.int32)
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(jnp.dtype(cfg.dtype))
        x, new_cache = self._backbone(params, x, "decode", cache=cache)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                            preferred_element_type=jnp.float32)[:, 0]
        new_cache["len"] = cache["len"] + 1
        return logits, new_cache

    def cache_defs(self, batch_size: int, max_len: int):
        cfg = self.cfg
        DI, H = cfg.d_inner, cfg.n_heads
        N = DI // H
        hd = cfg.d_model // H
        return {
            "ssm": {
                # NOTE: dim 3 is N+1 (normalizer channel) — never sharded
                "m": {"h": ParamDef((self.n_mlstm, batch_size, H, N + 1, N),
                                    ("layers", "batch", None, None, None),
                                    "zeros")},
                "s": {"c": ParamDef((self.n_slstm, batch_size, H, hd),
                                    ("layers", "batch", None, None), "zeros"),
                      "n": ParamDef((self.n_slstm, batch_size, H, hd),
                                    ("layers", "batch", None, None), "ones"),
                      "hp": ParamDef((self.n_slstm, batch_size, H, hd),
                                     ("layers", "batch", None, None), "zeros")},
            },
            "len": ParamDef((), (), "zeros"),
        }
