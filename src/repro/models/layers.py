"""Shared model layers: norms, RoPE, chunked (flash-style) attention, GLU
MLPs, embeddings, chunked cross-entropy.

Everything is pure JAX (`jax.lax` control flow) so every architecture
lowers/compiles for the dry-run on any backend.  Memory-critical paths are
chunked so no (S x S) score tensor or (B, S, V) logit tensor is ever
materialized:

* attention runs block-wise with an online-softmax accumulator
  (``lax.scan`` over KV blocks; optional "triangle" mode skips fully-masked
  future blocks — a §Perf lever that halves causal attention FLOPs);
* the LM loss scans over sequence chunks so vocab logits appear only in
  (B, chunk, V) tiles.

Sharding is annotated with logical names via ``repro.sharding.constrain``.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float):
    """x (..., S, H, hd), positions (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs  # (..., S, 1, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _online_softmax_step(m, l, acc, s, vb):
    """One flash-attention accumulation step; all f32."""
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bqkgt,btkd->bqkgd", p, vb, preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    mode: str = "masked"):
    """Block-wise attention with online softmax.

    q (B, Sq, H, hd); k/v (B, T, KVH, hd); GQA via H = KVH * G.
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    ``mode="triangle"``: python-unrolled q blocks, each scanning only the
    KV blocks at or before it (exact causal FLOPs); ``"masked"``: two
    nested scans over all blocks with masking (half the FLOPs wasted but
    the smallest HLO).
    """
    B, Sq0, H, hd = q.shape
    T0, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qc = min(Sq0, max(q_chunk, Sq0 // 16))
    kvc = min(T0, max(kv_chunk, T0 // 32))
    # pad ragged sequence lengths up to chunk multiples (masked below)
    Sq = -(-Sq0 // qc) * qc
    T = -(-T0 // kvc) * kvc
    if Sq != Sq0:
        q = jnp.pad(q, ((0, 0), (0, Sq - Sq0), (0, 0), (0, 0)))
    if T != T0:
        k = jnp.pad(k, ((0, 0), (0, T - T0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T - T0), (0, 0), (0, 0)))
    nq, nk = Sq // qc, T // kvc

    qb = (q.reshape(B, nq, qc, KVH, G, hd) * scale).astype(q.dtype)
    kb = k.reshape(B, nk, kvc, KVH, hd)
    vb = v.reshape(B, nk, kvc, KVH, hd)
    q_pos = q_offset + jnp.arange(Sq).reshape(nq, qc)

    def kv_scan(qi_block, q_block, kv_blocks):
        """Scan one q block over a stack of kv blocks (nb, B, kvc, KVH, hd)."""
        nb = kv_blocks[0].shape[0]
        m0 = jnp.full((B, qc, KVH, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, qc, KVH, G), jnp.float32)
        a0 = jnp.zeros((B, qc, KVH, G, hd), jnp.float32)

        def body(carry, inp):
            m, l, acc = carry
            kblk, vblk, kidx = inp
            s = jnp.einsum("bqkgd,btkd->bqkgt", q_block, kblk,
                           preferred_element_type=jnp.float32)
            kv_pos = kidx * kvc + jnp.arange(kvc)
            valid = kv_pos < T0  # ragged-length padding
            if causal:
                valid = valid[None, :] & (
                    q_pos[qi_block][:, None] >= kv_pos[None, :])
                s = jnp.where(valid[None, :, None, None, :], s, -jnp.inf)
            else:
                s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
            return _online_softmax_step(m, l, acc, s, vblk), None

        # checkpoint per KV step: without this, AD stacks every f32 score
        # block (s, p, masks) as scan residuals — measured at ~1/3 of total
        # HBM traffic and several GiB of peak memory.  With it, only the
        # small (m, l, acc) carries are saved; scores recompute in bwd.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                      (kv_blocks[0], kv_blocks[1],
                                       jnp.arange(nb) + kv_blocks[2]))
        l = jnp.maximum(l, 1e-30)
        return (acc / l[..., None]).astype(q.dtype)

    kb_s = jnp.moveaxis(kb, 1, 0)  # (nk, B, kvc, KVH, hd)
    vb_s = jnp.moveaxis(vb, 1, 0)

    if mode == "triangle" and causal:
        outs = []
        for qi in range(nq):
            # highest kv block this q block can see
            hi = min(((q_offset + (qi + 1) * qc - 1) // kvc) + 1, nk)
            outs.append(kv_scan(qi, qb[:, qi], (kb_s[:hi], vb_s[:hi], 0)))
        out = jnp.stack(outs, axis=1)  # (B, nq, qc, KVH, G, hd)
    else:
        def q_body(_, qi):
            return None, kv_scan(qi, qb[:, qi], (kb_s, vb_s, 0))
        _, out = jax.lax.scan(q_body, None, jnp.arange(nq))
        out = jnp.moveaxis(out, 0, 1)

    return out.reshape(B, Sq, H, hd)[:, :Sq0]


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-step attention: q (B, 1, H, hd) vs cache (B, T, KVH, hd).

    Positions >= cache_len are masked.  If the cache's sequence dim is
    sharded (long-context SP decode), XLA turns the softmax reductions into
    per-shard partials + cross-shard all-reduce — the log-sum-exp combine.
    """
    B, _, H, hd = q.shape
    T, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, hd) / math.sqrt(hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(T)[None, None, None, :] < cache_len
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def glu_mlp(x, wg, wu, wd, act: str):
    """SwiGLU / GeGLU block; x (B, S, D); w* 2-D."""
    f = jax.nn.silu if act == "silu" else partial(jax.nn.gelu, approximate=True)
    h = f(x @ wg) * (x @ wu)
    h = constrain(h, "batch", "seq", "ff")
    return h @ wd


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def sinusoid_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def chunked_softmax_xent(x, w_out, labels, mask=None, chunk: int = 512):
    """Mean cross-entropy without materializing (B, S, V) logits.

    x (B, S, D) final hidden states; w_out (D, V); labels (B, S) int32.
    Scans sequence chunks: per-chunk logits (B, c, V) live only inside the
    scan body.
    """
    B, S, D = x.shape
    c = min(chunk, S)
    n = S // c
    assert n * c == S
    xs = jnp.moveaxis(x.reshape(B, n, c, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)
    ms = None if mask is None else jnp.moveaxis(mask.reshape(B, n, c), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        if ms is None:
            xc, lc = inp
            mc = jnp.ones(lc.shape, jnp.float32)
        else:
            xc, lc, mc = inp
            mc = mc.astype(jnp.float32)
        logits = jnp.einsum("bcd,dv->bcv", xc, w_out,
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((logz - gold) * mc)
        cnt = cnt + jnp.sum(mc)
        return (tot, cnt), None

    xs_in = (xs, ls) if ms is None else (xs, ls, ms)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), xs_in)
    return tot / jnp.maximum(cnt, 1.0)
