"""Whisper-style encoder-decoder (audio backbone only).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model).  The encoder is
non-causal self-attention over frames with sinusoidal positions; the
decoder is causal self-attention + cross-attention with learned positions.

Shape semantics (DESIGN.md §6): ``seq_len`` is the *encoder* length;
decoder length is ``min(max_decoder_len, seq_len)`` for training and 1 for
decode, with per-layer cross-K/V of length seq_len held in the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (chunked_softmax_xent, decode_attention,
                                 flash_attention, glu_mlp, rms_norm,
                                 sinusoid_positions)
from repro.models.params import ParamDef
from repro.sharding import constrain


def _attn_defs(L, D, H, KVH, hd, prefix=""):
    return {
        prefix + "ln": ParamDef((L, D), ("layers", None), "zeros"),
        prefix + "wq": ParamDef((L, D, H * hd), ("layers", "fsdp", "heads")),
        prefix + "wk": ParamDef((L, D, KVH * hd), ("layers", "fsdp", "kv_heads")),
        prefix + "wv": ParamDef((L, D, KVH * hd), ("layers", "fsdp", "kv_heads")),
        prefix + "wo": ParamDef((L, H * hd, D), ("layers", "heads", "fsdp")),
    }


def _mlp_defs(L, D, F):
    return {
        "ln_mlp": ParamDef((L, D), ("layers", None), "zeros"),
        "w_gate": ParamDef((L, D, F), ("layers", "fsdp", "ff")),
        "w_up": ParamDef((L, D, F), ("layers", "fsdp", "ff")),
        "w_down": ParamDef((L, F, D), ("layers", "ff", "fsdp")),
    }


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.encoder_layers > 0

    def param_defs(self):
        cfg = self.cfg
        D, H, KVH, hd, F, V = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.d_ff, cfg.vocab_size)
        Le, Ld = cfg.encoder_layers, cfg.n_layers
        enc = {**_attn_defs(Le, D, H, KVH, hd), **_mlp_defs(Le, D, F)}
        dec = {**_attn_defs(Ld, D, H, KVH, hd),
               **_attn_defs(Ld, D, H, KVH, hd, prefix="x_"),
               **_mlp_defs(Ld, D, F)}
        return {
            "embed": ParamDef((V, D), ("vocab", "fsdp"), "embed"),
            "pos_dec": ParamDef((cfg.max_decoder_len, D), (None, None)),
            "enc": enc,
            "dec": dec,
            "enc_norm": ParamDef((D,), (None,), "zeros"),
            "final_norm": ParamDef((D,), (None,), "zeros"),
            "lm_head": ParamDef((D, V), ("fsdp", "vocab")),
        }

    # -- encoder --------------------------------------------------------------
    def encode(self, params, frames):
        cfg = self.cfg
        B, S, D = frames.shape
        x = frames.astype(jnp.dtype(cfg.dtype))
        x = x + sinusoid_positions(S, D).astype(x.dtype)[None]
        x = constrain(x, "batch", "seq", "embed")
        H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

        def body(xc, p):
            h = rms_norm(xc, p["ln"], cfg.norm_eps)
            q = (h @ p["wq"]).reshape(B, S, H, hd)
            k = (h @ p["wk"]).reshape(B, S, KVH, hd)
            v = (h @ p["wv"]).reshape(B, S, KVH, hd)
            q = constrain(q, "batch", "seq", "heads", "head_dim")
            a = flash_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
            xc = xc + a.reshape(B, S, H * hd) @ p["wo"]
            h2 = rms_norm(xc, p["ln_mlp"], cfg.norm_eps)
            xc = xc + glu_mlp(h2, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
            return xc, 0

        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder --------------------------------------------------------------
    def _decoder(self, params, tokens, memory, mode, cache=None, cache_len=None):
        """memory: encoder output (train/prefill) or None (decode — cached
        cross-K/V are used instead)."""
        cfg = self.cfg
        B, S = tokens.shape
        H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        if mode == "decode":
            pos = jax.lax.dynamic_slice_in_dim(params["pos_dec"], cache_len, 1)
            x = x + pos[None].astype(x.dtype)
        else:
            x = x + params["pos_dec"][None, :S].astype(x.dtype)
        positions = (jnp.arange(S)[None, :] if mode != "decode"
                     else jnp.full((B, 1), cache_len, jnp.int32))

        def body(carry, xs):
            xc = carry
            if mode == "decode":
                p, (ck, cv, xk, xv) = xs
            else:
                p = xs
            h = rms_norm(xc, p["ln"], cfg.norm_eps)
            q = (h @ p["wq"]).reshape(B, S, H, hd)
            k = (h @ p["wk"]).reshape(B, S, KVH, hd)
            v = (h @ p["wv"]).reshape(B, S, KVH, hd)
            if mode == "decode":
                ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                                  (0, cache_len, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                                  (0, cache_len, 0, 0))
                a = decode_attention(q, ck, cv, cache_len + 1)
            else:
                a = flash_attention(q, k, v, causal=True,
                                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
            xc = xc + a.reshape(B, S, H * hd) @ p["wo"]
            # cross attention
            h = rms_norm(xc, p["x_ln"], cfg.norm_eps)
            q = (h @ p["x_wq"]).reshape(B, S, H, hd)
            if mode == "decode":
                a = decode_attention(q, xk, xv, xk.shape[1])
            else:
                xk = (memory @ p["x_wk"]).reshape(B, -1, KVH, hd)
                xv = (memory @ p["x_wv"]).reshape(B, -1, KVH, hd)
                a = flash_attention(q, xk, xv, causal=False,
                                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
            xc = xc + a.reshape(B, S, H * hd) @ p["x_wo"]
            h2 = rms_norm(xc, p["ln_mlp"], cfg.norm_eps)
            xc = xc + glu_mlp(h2, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
            ys = 0
            if mode == "decode":
                ys = (ck, cv)
            elif mode == "prefill":
                ys = (k, v, xk, xv)
            return xc, ys

        fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
        xs = params["dec"] if mode != "decode" else (
            params["dec"], (cache["k"], cache["v"], cache["xk"], cache["xv"]))
        x, ys = jax.lax.scan(fn, x, xs)
        return x, ys

    # -- public API -------------------------------------------------------------
    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        memory = self.encode(params, batch["frames"])
        x, _ = self._decoder(params, tokens, memory, "train")
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
        return chunked_softmax_xent(x, params["lm_head"], labels, mask,
                                    chunk=min(512, tokens.shape[1]))

    def prefill(self, params, batch, max_len: int | None = None):
        """Encode frames + run the decoder prompt; cache self & cross K/V.
        (Self-KV is always padded to ``max_decoder_len``; ``max_len`` is
        accepted for API uniformity.)"""
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x, (k, v, xk, xv) = self._decoder(params, tokens, memory, "prefill")
        pad = cfg.max_decoder_len - S
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "xk": xk, "xv": xv,
            "len": jnp.full((), S, jnp.int32),
        }
        xl = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", xl, params["lm_head"],
                            preferred_element_type=jnp.float32)[:, 0]
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        clen = cache["len"]
        x, (k, v) = self._decoder(params, batch["tokens"], None, "decode",
                                  cache=cache, cache_len=clen)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"],
                            preferred_element_type=jnp.float32)[:, 0]
        new_cache = dict(cache)
        new_cache.update({"k": k, "v": v, "len": clen + 1})
        return logits, new_cache

    def cache_defs(self, batch_size: int, enc_len: int):
        cfg = self.cfg
        Ld, KVH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        self_kv = ParamDef((Ld, batch_size, cfg.max_decoder_len, KVH, hd),
                           ("layers", "batch", None, "kv_heads", "head_dim"),
                           "zeros")
        cross_kv = ParamDef((Ld, batch_size, enc_len, KVH, hd),
                            ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                            "zeros")
        return {"k": self_kv, "v": self_kv, "xk": cross_kv, "xv": cross_kv,
                "len": ParamDef((), (), "zeros")}
