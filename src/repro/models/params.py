"""Parameter definition DSL.

Models declare parameters as a nested tree of :class:`ParamDef` — shape +
logical sharding axes + initializer.  Everything else (allocation for smoke
tests, ShapeDtypeStructs for the dry-run, PartitionSpecs for pjit,
parameter counting for 6ND rooflines) derives from the same tree, so
config, sharding, and model code can never drift apart.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.specs import ShardingRules, logical_to_spec


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    logical: tuple              # logical axis name (or None) per dim
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 0.0          # 0 -> 1/sqrt(fan_in)

    def fan_in(self) -> int:
        return int(np.prod(self.shape[:-1])) if len(self.shape) > 1 else int(self.shape[0])


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def map_defs(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=_is_def)


def init_params(defs, seed: int, dtype) -> dict:
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(jax.random.PRNGKey(seed), max(len(leaves), 1))

    def mk(d: ParamDef, key):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        scale = d.scale or 1.0 / math.sqrt(max(d.fan_in(), 1))
        if d.init == "embed":
            scale = 0.02  # safe for tied input/output embeddings
        return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def shapedtypes(defs, dtype):
    return map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs)


def specs(defs, rules: ShardingRules):
    return map_defs(lambda d: logical_to_spec(d.logical, rules), defs)


def count(defs) -> int:
    leaves, _ = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    return int(sum(np.prod(d.shape) for d in leaves))
