"""Model API: build any assigned architecture, derive params/specs/inputs.

``build_model(cfg)`` returns a model object exposing:

* ``param_defs() / cache_defs(B, S)`` — ParamDef trees (see models.params)
* ``loss_fn(params, batch)`` — training loss
* ``prefill(params, batch) -> (logits, cache)``
* ``decode_step(params, cache, batch) -> (logits, cache)``

and this module adds the shape plumbing shared by the dry-run, the smoke
tests, and the launchers: input ShapeDtypeStructs per (arch x shape) cell,
sharding-rule selection per config, and 6ND model-FLOP accounting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import params as P
from repro.models.lm import TransformerLM
from repro.models.ssm import MambaLM, XLSTMLM
from repro.models.whisper import WhisperModel
from repro.sharding.specs import ShardingRules, decode_rules, logical_to_spec, train_rules


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family == "audio":
        return WhisperModel(cfg)
    if cfg.family == "hybrid" or (cfg.family == "ssm" and cfg.ssm_state):
        return MambaLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# sharding-rule selection (per config x mesh x step kind)
# ---------------------------------------------------------------------------

def rules_kind_is_decode(kind: str) -> bool:
    return kind.startswith("decode")


def rules_for(cfg: ModelConfig, mesh, kind: str, *, fsdp: bool | None = None,
              seq_shard: bool = False) -> ShardingRules:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    if fsdp is None:
        # FSDP whenever TP alone cannot comfortably fit the training state:
        # bf16 params + f32 grads + f32 Adam moments = 14 B/param
        n = P.count(build_model(cfg).param_defs())
        fsdp = (14 * n / model_size) > 8e9
    if kind == "train":
        rules = train_rules(sizes, fsdp=fsdp, seq_shard=seq_shard)
    else:
        # long-context decode: batch too small for the data axis -> shard
        # the KV/cross sequence over `data` instead (SP decode)
        rules = decode_rules(sizes, fsdp=fsdp, kv_seq_shard=kind == "decode_sp")
    over = {}
    # MoE placement: EP when experts divide the model axis, else TP-in-expert.
    # With EP the (E, C, D) dispatch buffers shard on E; without it they
    # shard on the capacity dim over the data axes (measured: C-sharding an
    # E-sharded buffer forces full-buffer reshard all-reduces — 9x worse).
    if cfg.n_experts:
        if cfg.n_experts % model_size == 0:
            over.update(experts="model", expert_ff=None, moe_cap=None)
        else:
            over.update(experts=None, expert_ff="model",
                        moe_cap=rules.axis("tokens"))
    # vocab that doesn't divide the model axis: replicate embeddings
    if cfg.vocab_size % model_size != 0:
        over.update(vocab=None)
    # attention-head divisibility:
    heads_div = cfg.n_heads % model_size == 0
    kvh_div = cfg.n_kv_heads % model_size == 0 if cfg.n_kv_heads else True
    hd_div = cfg.hd % model_size == 0
    if not heads_div:
        over.update(heads=None)
    if cfg.n_kv_heads and not kvh_div:
        if rules_kind_is_decode(kind) or not heads_div:
            # decode: the KV cache must shard -> split head_dim; the tiny
            # single-token scores psum across hd shards (cheap at S_q=1)
            over.update(kv_heads=None,
                        head_dim="model" if hd_div else None)
        else:
            # train/prefill: replicate KV, shard q heads; the model
            # expands GQA->MHA locally (see models.lm._kv_expand)
            over.update(kv_heads=None, head_dim=None)
    # SSM inner dim must divide the model axis; fall back to replicated
    if cfg.ssm_state and cfg.d_inner % model_size != 0:
        over.update(ssm_inner=None)
    if over:
        rules = rules.with_overrides(**over)
    return rules


# ---------------------------------------------------------------------------
# per-cell inputs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Input ShapeDtypeStructs for one (arch x shape) cell."""
    B = shape.global_batch
    S = shape.seq_len
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    emb = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        # seq_len = encoder frames (stub frontend -> embeddings); decoder text
        S_dec = min(cfg.max_decoder_len, S)
        if shape.kind == "train":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), emb),
                    "tokens": tok(B, S_dec)}
        if shape.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), emb),
                    "tokens": tok(B, S_dec)}
        return {"tokens": tok(B, 1)}
    base = {}
    if shape.kind in ("train", "prefill"):
        base["tokens"] = tok(B, S)
    else:
        base["tokens"] = tok(B, 1)
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        base["vision_embed"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), emb)
    return base


def batch_logical(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    log = {"tokens": ("batch", "seq") if shape.kind != "decode" else ("batch", None)}
    if cfg.family == "audio" and shape.kind != "decode":
        log["frames"] = ("batch", "seq", "embed")
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        log["vision_embed"] = ("batch", None, "embed")
    return log


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules):
    return {k: logical_to_spec(v, rules)
            for k, v in batch_logical(cfg, shape).items()}


def cache_struct_and_specs(model, cfg: ModelConfig, shape: ShapeConfig,
                           rules: ShardingRules):
    """Decode-cell cache: ShapeDtypeStructs + PartitionSpecs."""
    defs = model.cache_defs(shape.global_batch, shape.seq_len)
    f32 = {"len"}

    def sds(d: P.ParamDef, name_hint=None):
        dt = jnp.int32 if d.shape == () else (
            jnp.float32 if len(d.shape) == 5 and d.shape[-1] == d.shape[-2] + 0
            else jnp.dtype(cfg.dtype))
        return jax.ShapeDtypeStruct(d.shape, dt)

    # simpler: kv caches in model dtype, ssm states f32, len int32
    def sds2(path, d):
        if d.shape == ():
            return jax.ShapeDtypeStruct((), jnp.int32)
        if "ssm" in path:
            dt = jnp.float32 if path[-1] in ("h", "c", "n", "hp") else jnp.dtype(cfg.dtype)
            return jax.ShapeDtypeStruct(d.shape, dt)
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(cfg.dtype))

    structs = _map_with_path(sds2, defs)
    specs = P.specs(defs, rules)
    return structs, specs


def cache_init(model, cfg: ModelConfig, batch_size: int, max_len: int):
    """Allocated zero cache (smoke tests / serving)."""
    defs = model.cache_defs(batch_size, max_len)

    def mk(path, d):
        if d.shape == ():
            return jnp.zeros((), jnp.int32)
        if "ssm" in path:
            dt = jnp.float32 if path[-1] in ("h", "c", "n", "hp") else jnp.dtype(cfg.dtype)
        else:
            dt = jnp.dtype(cfg.dtype)
        fill = jnp.ones if d.init == "ones" else jnp.zeros
        return fill(d.shape, dt)

    return _map_with_path(mk, defs)


def _map_with_path(fn, tree, path=()):
    if isinstance(tree, dict):
        return {k: _map_with_path(fn, v, path + (k,)) for k, v in tree.items()}
    if isinstance(tree, tuple):
        return tuple(_map_with_path(fn, v, path + (str(i),))
                     for i, v in enumerate(tree))
    return fn(path, tree)


# ---------------------------------------------------------------------------
# 6ND model-FLOP accounting (roofline numerator)
# ---------------------------------------------------------------------------

def n_params(cfg: ModelConfig) -> int:
    return P.count(build_model(cfg).param_defs())


def n_active_params(cfg: ModelConfig) -> int:
    """MoE: only top_k of n_experts expert params are active per token."""
    if not cfg.n_experts:
        return n_params(cfg)
    model = build_model(cfg)
    defs = model.param_defs()
    total = P.count(defs)
    expert = sum(P.count({k: v}) for k, v in defs["layers"].items()
                 if k.startswith("we_"))
    return total - expert + expert * cfg.top_k // cfg.n_experts


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D tokens (train) / 2*N*D (inference step)."""
    n = n_active_params(cfg)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        if cfg.family == "audio":
            toks = shape.global_batch * (shape.seq_len
                                         + min(cfg.max_decoder_len, shape.seq_len))
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # one decoded token per sequence
