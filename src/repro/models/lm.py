"""Decoder-only transformer LM: dense GQA (yi / codeqwen / gemma / qwen3),
MoE (grok / qwen3-moe), and VLM with interleaved gated cross-attention
(llama-3.2-vision).

Layers run under ``jax.lax.scan`` over a stacked parameter tree (small HLO,
fast compile at 512 devices); activation checkpointing via
``jax.checkpoint`` per block when ``cfg.remat``.  For the VLM family the
stack is split into ``cross_attn_every``-sized groups so cross-attention
blocks execute between scans (exact FLOP accounting — no dead branches in
the HLO).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.layers import (chunked_softmax_xent, decode_attention,
                                 flash_attention, glu_mlp, rms_norm, rope)
from repro.models.params import ParamDef
from repro.sharding import constrain
from repro.sharding.specs import current_rules


def _kv_expand(cfg: ModelConfig) -> bool:
    """GQA -> MHA expansion when kv heads can't shard the model axis.

    With kv_heads % model != 0, sharding head_dim instead collapses the
    score-block arithmetic intensity (2 flops/byte at hd/16 contraction —
    memory-bound by ~40x, measured in the dry-run).  Expanding K/V to the
    full head count keeps every chip's attention fully local: the repeat
    is sharded on `heads`, so each chip materializes only its own slice.
    """
    r = current_rules()
    return (r is not None and cfg.n_kv_heads < cfg.n_heads
            and r.size("kv_heads") == 1 and r.size("heads") > 1
            and r.size("head_dim") == 1)


# ---------------------------------------------------------------------------
# sublayers
# ---------------------------------------------------------------------------

def self_attention(p, x, positions, cfg: ModelConfig, kv_cache=None,
                   cache_len=None):
    """Pre-norm GQA self-attention sublayer.

    Returns (x + attn_out, new_kv):
    * train:      kv_cache None -> new_kv None
    * prefill:    kv_cache "collect" -> new_kv = (k, v) full sequence
    * decode:     kv_cache (k_buf, v_buf) -> new_kv = updated buffers
    """
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (h @ p["wk"]).reshape(B, S, KVH, hd)
    v = (h @ p["wv"]).reshape(B, S, KVH, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")

    expand = _kv_expand(cfg)
    if kv_cache is None or kv_cache == "collect":
        ka, va = k, v
        if expand:
            g = H // KVH
            ka = constrain(jnp.repeat(k, g, axis=2),
                           "batch", "seq", "heads", "head_dim")
            va = constrain(jnp.repeat(v, g, axis=2),
                           "batch", "seq", "heads", "head_dim")
        attn = flash_attention(q, ka, va, causal=True,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                               mode=cfg.causal_mode)
        new_kv = (k, v) if kv_cache == "collect" else None
    else:
        k_buf, v_buf = kv_cache
        k_buf = jax.lax.dynamic_update_slice(k_buf, k.astype(k_buf.dtype),
                                             (0, cache_len, 0, 0))
        v_buf = jax.lax.dynamic_update_slice(v_buf, v.astype(v_buf.dtype),
                                             (0, cache_len, 0, 0))
        ka, va = k_buf, v_buf
        if expand:
            g = H // KVH
            ka = constrain(jnp.repeat(k_buf, g, axis=2),
                           "batch", "kv_seq", "heads", "head_dim")
            va = constrain(jnp.repeat(v_buf, g, axis=2),
                           "batch", "kv_seq", "heads", "head_dim")
        attn = decode_attention(q, ka, va, cache_len + S)
        new_kv = (k_buf, v_buf)
    out = attn.reshape(B, S, H * hd) @ p["wo"]
    out = constrain(out, "batch", "seq", "embed")
    return x + out, new_kv


def cross_attention(p, x, memory, cfg: ModelConfig):
    """Gated cross-attention (llama-3.2-vision style); memory (B, T, D)."""
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (memory @ p["wk"]).reshape(B, -1, KVH, hd)
    v = (memory @ p["wv"]).reshape(B, -1, KVH, hd)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    attn = flash_attention(q, k, v, causal=False,
                           q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = attn.reshape(B, S, H * hd) @ p["wo"]
    gate = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype)
    return x + gate * out


def mlp_sublayer(p, x, cfg: ModelConfig):
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.n_experts:
        block_fn = (moe_mod.moe_block_rowwise if cfg.moe_dispatch == "rowwise"
                    else moe_mod.moe_block)
        out, probs = block_fn(
            h, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, act=cfg.act)
        aux = moe_mod.moe_aux_loss(probs.reshape(-1, probs.shape[-1]))
        return x + out, aux
    out = glu_mlp(h, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
    return x + out, jnp.float32(0)


def block(p, x, positions, cfg: ModelConfig, kv_cache=None, cache_len=None):
    x, new_kv = self_attention(p, x, positions, cfg, kv_cache, cache_len)
    x, aux = mlp_sublayer(p, x, cfg)
    return x, new_kv, aux


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.family in ("dense", "moe", "vlm")
        if cfg.family == "vlm":
            assert cfg.n_layers % cfg.cross_attn_every == 0

    # -- parameters ---------------------------------------------------------
    def param_defs(self):
        cfg = self.cfg
        L, D, H, KVH, hd = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                            cfg.n_kv_heads, cfg.hd)
        V, F = cfg.vocab_size, cfg.d_ff
        layer = {
            "ln_attn": ParamDef((L, D), ("layers", None), "zeros"),
            "wq": ParamDef((L, D, H * hd), ("layers", "fsdp", "heads")),
            "wk": ParamDef((L, D, KVH * hd), ("layers", "fsdp", "kv_heads")),
            "wv": ParamDef((L, D, KVH * hd), ("layers", "fsdp", "kv_heads")),
            "wo": ParamDef((L, H * hd, D), ("layers", "heads", "fsdp")),
            "ln_mlp": ParamDef((L, D), ("layers", None), "zeros"),
        }
        if cfg.qk_norm:
            layer["q_norm"] = ParamDef((L, hd), ("layers", None), "zeros")
            layer["k_norm"] = ParamDef((L, hd), ("layers", None), "zeros")
        if cfg.n_experts:
            E, Fe = cfg.n_experts, (cfg.moe_d_ff or cfg.d_ff)
            layer.update({
                "router": ParamDef((L, D, E), ("layers", None, None)),
                "we_gate": ParamDef((L, E, D, Fe), ("layers", "experts", "fsdp", "expert_ff")),
                "we_up": ParamDef((L, E, D, Fe), ("layers", "experts", "fsdp", "expert_ff")),
                "we_down": ParamDef((L, E, Fe, D), ("layers", "experts", "expert_ff", "fsdp")),
            })
        else:
            layer.update({
                "w_gate": ParamDef((L, D, F), ("layers", "fsdp", "ff")),
                "w_up": ParamDef((L, D, F), ("layers", "fsdp", "ff")),
                "w_down": ParamDef((L, F, D), ("layers", "ff", "fsdp")),
            })
        defs = {
            "embed": ParamDef((V, D), ("vocab", "fsdp"), "embed"),
            "layers": layer,
            "final_norm": ParamDef((D,), (None,), "zeros"),
        }
        if not cfg.tie_embeddings:
            defs["lm_head"] = ParamDef((D, V), ("fsdp", "vocab"))
        if cfg.family == "vlm":
            nC = cfg.n_layers // cfg.cross_attn_every
            defs["cross"] = {
                "ln": ParamDef((nC, D), (None, None), "zeros"),
                "wq": ParamDef((nC, D, H * hd), (None, "fsdp", "heads")),
                "wk": ParamDef((nC, D, KVH * hd), (None, "fsdp", "kv_heads")),
                "wv": ParamDef((nC, D, KVH * hd), (None, "fsdp", "kv_heads")),
                "wo": ParamDef((nC, H * hd, D), (None, "heads", "fsdp")),
                "gate": ParamDef((nC,), (None,), "zeros"),
            }
        return defs

    # -- forward ------------------------------------------------------------
    def _backbone(self, params, x, positions, batch, mode: str,
                  cache=None, cache_len=None):
        """mode: train | prefill | decode.  Returns (x, new_cache, aux)."""
        cfg = self.cfg

        def blk(p, x, kv, clen):
            kv_arg = {"train": None, "prefill": "collect", "decode": kv}[mode]
            return block(p, x, positions, cfg, kv_arg, clen)

        if cfg.remat and mode == "train":
            blk = jax.checkpoint(blk, static_argnums=())

        def scan_stack(stack_params, x, cache_slice, layer0: int = 0):
            if mode == "decode":
                # carry the FULL cache through the scan and update in
                # place: scan-xs/ys cache threading double-buffers the
                # whole KV cache in HBM (measured +8..14 GiB/chip);
                # while-loop carries alias, and only the one new token
                # position is written per layer.
                kf, vf = cache_slice  # (L, B, T, KVH, hd)

                def body(carry, p):
                    xc, aux, kfc, vfc, li = carry
                    ck = jax.lax.dynamic_index_in_dim(kfc, li, 0, keepdims=False)
                    cv = jax.lax.dynamic_index_in_dim(vfc, li, 0, keepdims=False)
                    xc, (nk, nv), a = blk(p, xc, (ck, cv), cache_len)
                    # nk/nv differ from ck/cv only at [*, cache_len, ...]:
                    # write back just that token slot
                    tok_k = jax.lax.dynamic_slice_in_dim(nk, cache_len, 1, 1)
                    tok_v = jax.lax.dynamic_slice_in_dim(nv, cache_len, 1, 1)
                    kfc = jax.lax.dynamic_update_slice(
                        kfc, tok_k[None].astype(kfc.dtype),
                        (li, 0, cache_len, 0, 0))
                    vfc = jax.lax.dynamic_update_slice(
                        vfc, tok_v[None].astype(vfc.dtype),
                        (li, 0, cache_len, 0, 0))
                    return (xc, aux + a, kfc, vfc, li + 1), None

                (x, aux, kf, vf, _), _ = jax.lax.scan(
                    body, (x, jnp.float32(0), kf, vf, jnp.int32(layer0)),
                    stack_params)
                return x, (kf, vf), aux

            def body(carry, p):
                xc, aux = carry
                xc, new_kv, a = blk(p, xc, None, cache_len)
                return (xc, aux + a), (new_kv if new_kv is not None else 0)

            (x, aux), kv_stack = jax.lax.scan(body, (x, jnp.float32(0)),
                                              stack_params)
            return x, kv_stack, aux

        if cfg.family == "vlm":
            every = cfg.cross_attn_every
            nG = cfg.n_layers // every
            vis = batch["vision_embed"].astype(x.dtype)
            regroup = jax.tree_util.tree_map(
                lambda a: a.reshape((nG, every) + a.shape[1:]), params["layers"])
            aux = jnp.float32(0)
            kvs = []
            cur_kv = None if cache is None else cache["kv"]  # threaded, full
            for g in range(nG):
                cp = jax.tree_util.tree_map(lambda a: a[g], params["cross"])
                x = cross_attention(cp, x, vis, cfg)
                gp = jax.tree_util.tree_map(lambda a: a[g], regroup)
                if mode == "decode":
                    x, cur_kv, a = scan_stack(gp, x, cur_kv, layer0=g * every)
                else:
                    x, kv_stack, a = scan_stack(gp, x, None)
                    kvs.append(kv_stack)
                aux = aux + a
            new_cache = None
            if mode == "decode":
                new_cache = {"kv": cur_kv}
            elif mode == "prefill":
                kv = jax.tree_util.tree_map(
                    lambda *gs: jnp.concatenate(gs, axis=0), *kvs)
                new_cache = {"kv": kv}
            return x, new_cache, aux

        cache_slice = None if cache is None else cache["kv"]
        x, kv_stack, aux = scan_stack(params["layers"], x, cache_slice)
        new_cache = {"kv": kv_stack} if mode in ("prefill", "decode") else None
        return x, new_cache, aux

    def _embed_in(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.family == "vlm" or True:
            x = constrain(x, "batch", "seq", "embed")
        return x.astype(jnp.dtype(self.cfg.dtype))

    def _head(self, params):
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])

    # -- public API -----------------------------------------------------------
    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed_in(params, tokens)
        positions = jnp.arange(S)[None, :]
        x, _, aux = self._backbone(params, x, positions, batch, "train")
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
        loss = chunked_softmax_xent(x, self._head(params), labels, mask)
        return loss + 0.01 * aux / max(cfg.n_layers, 1)

    def prefill(self, params, batch, max_len: int | None = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed_in(params, tokens)
        positions = jnp.arange(S)[None, :]
        x, cache, _ = self._backbone(params, x, positions, batch, "prefill")
        if max_len is not None and max_len > S:
            cache["kv"] = jax.tree_util.tree_map(
                lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, max_len - S),
                                      (0, 0), (0, 0))), cache["kv"])
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, self._head(params),
                            preferred_element_type=jnp.float32)
        cache["len"] = jnp.full((), S, jnp.int32)
        if cfg.family == "vlm":
            cache["vision_embed"] = batch["vision_embed"]
        return logits[:, 0], cache

    def decode_step(self, params, cache, batch):
        """One token for every sequence in the batch; cache updated in place."""
        cfg = self.cfg
        tokens = batch["tokens"]            # (B, 1)
        B = tokens.shape[0]
        clen = cache["len"]
        x = self._embed_in(params, tokens)
        positions = jnp.full((B, 1), clen, jnp.int32)
        dec_batch = dict(batch)
        if cfg.family == "vlm":
            dec_batch["vision_embed"] = cache["vision_embed"]
        x, new_cache, _ = self._backbone(params, x, positions, dec_batch,
                                         "decode", cache=cache, cache_len=clen)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, self._head(params),
                            preferred_element_type=jnp.float32)[:, 0]
        new_cache["len"] = clen + 1
        if cfg.family == "vlm":
            new_cache["vision_embed"] = cache["vision_embed"]
        return logits, new_cache

    # -- cache layout -----------------------------------------------------------
    def cache_defs(self, batch_size: int, max_len: int):
        cfg = self.cfg
        L, KVH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        kv = ParamDef((L, batch_size, max_len, KVH, hd),
                      ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                      "zeros")
        defs = {"kv": (kv, kv), "len": ParamDef((), (), "zeros")}
        if cfg.family == "vlm":
            defs["vision_embed"] = ParamDef(
                (batch_size, cfg.vision_tokens, cfg.d_model),
                ("batch", None, "embed"), "zeros")
        return defs
