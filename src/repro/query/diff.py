"""Cross-run regression analysis: diff two databases on the unified CCT.

Two runs of the same application produce different context *ids* (each
run's unified CCT depends on which call paths its profiles observed), so
alignment is by **call path**: a context in run A matches the context in
run B with the same root-to-node path.  Costs come from each database's
summary-statistics section — a diff reads zero planes from either store.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.query.database import Database


@dataclass(frozen=True)
class DiffEntry:
    """One aligned call path and its cost under each run.

    ``std_a``/``std_b`` carry the per-context cross-profile standard
    deviation from each run's summary stats — the raw material for noise
    bands (a delta smaller than the run's own internal spread is weather,
    not climate).
    """

    path: str
    ctx_a: int | None     # context id in run A (None: path only in B)
    ctx_b: int | None     # context id in run B (None: path only in A)
    a: float
    b: float
    std_a: float = 0.0    # per-context std across profiles in run A
    std_b: float = 0.0    # per-context std across profiles in run B

    @property
    def delta(self) -> float:
        return self.b - self.a

    @property
    def ratio(self) -> float:
        return self.b / self.a if self.a else float("inf")

    def as_dict(self) -> dict:
        return {"path": self.path, "ctx_a": self.ctx_a, "ctx_b": self.ctx_b,
                "a": self.a, "b": self.b, "delta": self.delta,
                "std_a": self.std_a, "std_b": self.std_b}


# how to fold two same-path contexts' stats into one path-level stat;
# mean/std are not foldable without counts, so they fail loudly instead
# of reporting a silently wrong number
_COMBINE = {"sum": lambda a, b: a + b, "count": lambda a, b: a + b,
            "max": max, "min": min}


def _metric_by_path(db: Database, metric, stat: str, inclusive: bool
                    ) -> dict[str, tuple[int, float]]:
    return {p: (c, v) for p, (c, v, _s) in
            metric_stats_by_path(db, metric, stat, inclusive).items()}


def metric_stats_by_path(db: Database, metric, stat: str, inclusive: bool
                         ) -> dict[str, tuple[int, float, float]]:
    """``{path: (ctx, value, std)}`` for one metric; tolerant of absence.

    A metric that exists in only one run resolves to an empty mapping here
    rather than raising — its paths then diff against 0 on the missing
    side, which is exactly the new/vanished shape a regression hunt wants.
    ``std`` is the per-context standard deviation across the run's own
    profiles; paths folding several contexts keep the largest std (the
    conservative noise estimate).
    """
    try:
        ctx_ids, rows = db.metric_entries(metric, inclusive=inclusive)
    except (KeyError, ValueError, IndexError):
        return {}
    vals = db.stats[stat][rows]
    stds = db.stats["std"][rows]
    out: dict[str, tuple[int, float, float]] = {}
    for c, v, s in zip(ctx_ids, vals, stds):
        path = db.path_of(int(c))
        prev = out.get(path)
        if prev is None:
            out[path] = (int(c), float(v), float(s))
            continue
        # distinct contexts can share a path string (same name, different
        # node kind): fold them — the diff unit is the call path
        fold = _COMBINE.get(stat)
        if fold is None:
            raise ValueError(
                f"stat {stat!r} cannot be folded across the {len(ctx_ids)} "
                f"contexts sharing path {path!r}; use sum/count/max/min")
        out[path] = (prev[0], fold(prev[1], float(v)), max(prev[2], float(s)))
    return out


def diff(db_a: Database, db_b: Database, metric, *, stat: str = "sum",
         inclusive: bool = True, top: int | None = None,
         min_abs_delta: float = 0.0) -> list[DiffEntry]:
    """Per-call-path cost deltas between two runs, largest first.

    Contexts present in only one run appear with the other side at 0 —
    exactly the new/vanished call paths a regression hunt wants surfaced.
    Ordering is deterministic: ``(-|delta|, path)``.  ``top`` truncates;
    ``min_abs_delta`` filters noise (and drops exact ties at 0.0).
    """
    by_a = metric_stats_by_path(db_a, metric, stat, inclusive)
    by_b = metric_stats_by_path(db_b, metric, stat, inclusive)
    out: list[DiffEntry] = []
    for path in by_a.keys() | by_b.keys():
        ca, va, sa = by_a.get(path, (None, 0.0, 0.0))
        cb, vb, sb = by_b.get(path, (None, 0.0, 0.0))
        if abs(vb - va) < min_abs_delta or (min_abs_delta == 0.0 and vb == va):
            continue
        out.append(DiffEntry(path=path, ctx_a=ca, ctx_b=cb, a=va, b=vb,
                             std_a=sa, std_b=sb))
    out.sort(key=lambda e: (-abs(e.delta), e.path))
    return out[:top] if top is not None else out


def total_delta(db_a: Database, db_b: Database, metric, *,
                stat: str = "sum") -> tuple[float, float]:
    """Whole-run exclusive-cost totals ``(total_a, total_b)`` for a metric.

    Uses exclusive entries only so the total is not inflated by ancestor
    propagation; zero plane reads.
    """
    ta = sum(v for _, v in _metric_by_path(db_a, metric, stat, False).values())
    tb = sum(v for _, v in _metric_by_path(db_b, metric, stat, False).values())
    return float(ta), float(tb)
