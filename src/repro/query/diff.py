"""Cross-run regression analysis: diff two databases on the unified CCT.

Two runs of the same application produce different context *ids* (each
run's unified CCT depends on which call paths its profiles observed), so
alignment is by **call path**: a context in run A matches the context in
run B with the same root-to-node path.  Costs come from each database's
summary-statistics section — a diff reads zero planes from either store.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.query.database import Database


@dataclass(frozen=True)
class DiffEntry:
    """One aligned call path and its cost under each run."""

    path: str
    ctx_a: int | None     # context id in run A (None: path only in B)
    ctx_b: int | None     # context id in run B (None: path only in A)
    a: float
    b: float

    @property
    def delta(self) -> float:
        return self.b - self.a

    @property
    def ratio(self) -> float:
        return self.b / self.a if self.a else float("inf")

    def as_dict(self) -> dict:
        return {"path": self.path, "ctx_a": self.ctx_a, "ctx_b": self.ctx_b,
                "a": self.a, "b": self.b, "delta": self.delta}


# how to fold two same-path contexts' stats into one path-level stat;
# mean/std are not foldable without counts, so they fail loudly instead
# of reporting a silently wrong number
_COMBINE = {"sum": lambda a, b: a + b, "count": lambda a, b: a + b,
            "max": max, "min": min}


def _metric_by_path(db: Database, metric, stat: str, inclusive: bool
                    ) -> dict[str, tuple[int, float]]:
    ctx_ids, rows = db.metric_entries(metric, inclusive=inclusive)
    vals = db.stats[stat][rows]
    out: dict[str, tuple[int, float]] = {}
    for c, v in zip(ctx_ids, vals):
        path = db.path_of(int(c))
        prev = out.get(path)
        if prev is None:
            out[path] = (int(c), float(v))
            continue
        # distinct contexts can share a path string (same name, different
        # node kind): fold them — the diff unit is the call path
        fold = _COMBINE.get(stat)
        if fold is None:
            raise ValueError(
                f"stat {stat!r} cannot be folded across the {len(ctx_ids)} "
                f"contexts sharing path {path!r}; use sum/count/max/min")
        out[path] = (prev[0], fold(prev[1], float(v)))
    return out


def diff(db_a: Database, db_b: Database, metric, *, stat: str = "sum",
         inclusive: bool = True, top: int | None = None,
         min_abs_delta: float = 0.0) -> list[DiffEntry]:
    """Per-call-path cost deltas between two runs, largest first.

    Contexts present in only one run appear with the other side at 0 —
    exactly the new/vanished call paths a regression hunt wants surfaced.
    Ordering is deterministic: ``(-|delta|, path)``.  ``top`` truncates;
    ``min_abs_delta`` filters noise (and drops exact ties at 0.0).
    """
    by_a = _metric_by_path(db_a, metric, stat, inclusive)
    by_b = _metric_by_path(db_b, metric, stat, inclusive)
    out: list[DiffEntry] = []
    for path in by_a.keys() | by_b.keys():
        ca, va = by_a.get(path, (None, 0.0))
        cb, vb = by_b.get(path, (None, 0.0))
        if abs(vb - va) < min_abs_delta or (min_abs_delta == 0.0 and vb == va):
            continue
        out.append(DiffEntry(path=path, ctx_a=ca, ctx_b=cb, a=va, b=vb))
    out.sort(key=lambda e: (-abs(e.delta), e.path))
    return out[:top] if top is not None else out


def total_delta(db_a: Database, db_b: Database, metric, *,
                stat: str = "sum") -> tuple[float, float]:
    """Whole-run exclusive-cost totals ``(total_a, total_b)`` for a metric.

    Uses exclusive entries only so the total is not inflated by ancestor
    propagation; zero plane reads.
    """
    ta = sum(v for _, v in _metric_by_path(db_a, metric, stat, False).values())
    tb = sum(v for _, v in _metric_by_path(db_b, metric, stat, False).values())
    return float(ta), float(tb)
