"""Postmortem database handle: one open per store, routed reads, LRU cache.

The paper's sparse formats exist to be *read* (§3): PMS answers
"all metrics of profile p" with one plane read, CMS answers "metric m of
context c across all profiles" with one stripe read.  :class:`Database`
packages both stores (plus the integrated trace file) behind a single
handle:

* the meta-database — unified CCT, summary statistics, metric registry,
  profile identities — is parsed **once** at open; the PMS/CMS data regions
  are ``mmap``-ed so plane reads are slices, not syscalls;
* every query is routed to the cheaper store: profile-major -> a PMS plane,
  context-major -> a CMS context plane, point lookups -> whichever plane is
  smaller (or already cached);
* decoded planes land in a byte-budgeted :class:`~repro.query.cache.LRUCache`
  shared by all query shapes, so repeated and bursty access patterns (the
  interactive-browser workload of §3) hit memory, not disk.

Routing is observable: ``db.counters`` records how many planes each store
served, which is how tests pin down that context-major queries never scan
PMS planes.
"""
from __future__ import annotations

import mmap
import os

import numpy as np

from repro.core.cms import (CMSReader, decode_plane, empty_plane,
                            stripe_from_buffer, stripe_from_plane)
from repro.core.metrics import INCLUSIVE_BIT, MetricRegistry
from repro.core.pms import PMSReader
from repro.core.sparse import SparseMetrics, Trace
from repro.core.stats import pack_keys
from repro.core.traces import TraceDBReader
from repro.obs import MetricsRegistry
from repro.query.cache import LRUCache

PMS_NAME, CMS_NAME, TRC_NAME = "db.pms", "db.cms", "db.trc"


class Database:
    """Read-only handle over one analysis run's PMS + CMS + trace databases.

    ``Database(db_dir)`` opens ``db.pms`` (required) and ``db.cms`` /
    ``db.trc`` (optional — queries that need a missing store either fall
    back or raise, see each method).  Also accepts an explicit
    ``pms_path=`` when the databases do not share a directory.
    """

    def __init__(self, db_dir=None, *, pms_path=None, cms_path=None,
                 trace_path=None, cache_bytes: int = 64 << 20):
        self.db_dir = None if db_dir is None else str(db_dir)
        if db_dir is not None:
            db_dir = str(db_dir)
            pms_path = pms_path or os.path.join(db_dir, PMS_NAME)
            cand = cms_path or os.path.join(db_dir, CMS_NAME)
            cms_path = cand if os.path.exists(cand) else None
            cand = trace_path or os.path.join(db_dir, TRC_NAME)
            trace_path = cand if os.path.exists(cand) else None
        if pms_path is None:
            raise ValueError("Database needs a db_dir or an explicit pms_path")

        # one open + one meta parse per store, held for the handle's lifetime
        self._pms = PMSReader(pms_path)
        self._pms_mm = mmap.mmap(self._pms._fd, 0, access=mmap.ACCESS_READ)
        self._cms = None
        self._cms_mm = None
        if cms_path is not None:
            self._cms = CMSReader(cms_path)
            self._cms_mm = mmap.mmap(self._cms._fd, 0, access=mmap.ACCESS_READ)
        self._trc = TraceDBReader(trace_path) if trace_path is not None else None

        self.tree = self._pms.tree
        self.stats = self._pms.stats
        self.n_profiles = self._pms.n_profiles
        self.n_contexts = len(self.tree.parent) if self.tree is not None else 0
        reg_json = self._pms.meta.get("registry") or []
        self.registry = MetricRegistry.from_json(reg_json) if reg_json else None
        # summary stats are sorted by packed (ctx << 16 | mid) key (the
        # StatsAccumulator invariant): point lookups are one binary search
        self._stat_keys = (pack_keys(self.stats["ctx"], self.stats["mid"])
                           if self.stats else np.empty(0, np.uint64))

        # snapshot epoch this handle serves, when opened from a versioned
        # snapshot root (open_current / EpochSwitcher); None for plain dirs
        self.epoch: int | None = None

        self.cache = LRUCache(cache_bytes)
        # counters live on an obs registry so the serving layer can render
        # them over Prometheus; CounterGroup keeps the dict surface every
        # caller (tests, benchmarks) already uses, with a lock inside —
        # `+=` on a bare dict slot is not atomic and the serving layer
        # drives one handle from many threads
        self.obs = MetricsRegistry()
        self.counters = self.obs.group(
            "db", {"pms_plane_loads": 0, "cms_plane_loads": 0,
                   "cms_stripe_reads": 0, "cms_stripe_skips": 0,
                   "trace_loads": 0, "pms_scan_fallbacks": 0})
        for name, fn in (("db.cache_hits", lambda: self.cache.hits),
                         ("db.cache_misses", lambda: self.cache.misses),
                         ("db.cache_evictions", lambda: self.cache.evictions),
                         ("db.cache_bytes", lambda: self.cache.nbytes),
                         ("db.cache_capacity_bytes",
                          lambda: self.cache.capacity_bytes)):
            self.obs.gauge(name, fn)

    @classmethod
    def open_current(cls, root, *, cache_bytes: int = 64 << 20) -> "Database":
        """Open the epoch a snapshot root's ``CURRENT`` pointer names.

        One-shot resolution (postmortem reads, tests); a serving process
        that must *track* the pointer uses
        :class:`repro.query.epoch.EpochSwitcher` instead.  Raises
        :class:`~repro.ingest.snapshot.SnapshotGone` when the pointed-at
        epoch directory lost a race with the publisher's GC — re-resolve
        and retry.
        """
        from repro.ingest.snapshot import SnapshotGone, read_current
        cur = read_current(root)
        if cur is None:
            raise FileNotFoundError(f"no CURRENT pointer under {root}")
        epoch, db_dir = cur
        try:
            db = cls(db_dir, cache_bytes=cache_bytes)
        except (FileNotFoundError, OSError) as e:
            raise SnapshotGone(
                f"epoch {epoch} dir vanished under {root}") from e
        db.epoch = epoch
        return db

    def _count(self, key: str) -> None:
        self.counters.inc(key)

    # -- identity / naming ---------------------------------------------------
    @property
    def has_cms(self) -> bool:
        return self._cms is not None

    @property
    def has_traces(self) -> bool:
        return self._trc is not None

    def trace_lengths(self) -> np.ndarray:
        """Per-profile trace sample counts straight from the in-memory toc.

        Zero segment decodes: the toc's second column *is* the sample
        count, so rank-activity shape (who sampled how much) is readable
        at file-open cost — the straggler analyzer's whole input.  Empty
        array when the database carries no trace store.
        """
        if self._trc is None:
            return np.zeros(0, dtype=np.int64)
        return self._trc.toc[:, 1].astype(np.int64)

    def identity(self, pid: int) -> dict | None:
        return self._pms.identity(pid)

    def path_of(self, ctx: int) -> str:
        return self.tree.full_path(int(ctx))

    def resolve_metric(self, metric, *, inclusive: bool = False) -> int:
        """Metric name or id -> concrete mid; ``inclusive`` ORs the bit.

        Names need a registry in the database meta; the ``":I"`` suffix
        selects the propagated inclusive variant (``foo:I`` == ``foo`` with
        ``inclusive=True``).
        """
        if isinstance(metric, str):
            name = metric
            if name.endswith(":I"):
                name, inclusive = name[:-2], True
            if self.registry is None:
                raise ValueError(
                    f"metric {metric!r} given by name but the database has "
                    f"no metric registry; use an integer metric id")
            mid = self.registry[name].mid
        else:
            mid = int(metric)
        return mid | INCLUSIVE_BIT if inclusive else mid

    # -- plane loads (the only code that touches the stores) -----------------
    def profile_metrics(self, pid: int) -> SparseMetrics:
        """All metrics of profile ``pid``: one PMS plane (paper §3.2)."""
        pid = int(pid)

        def load():
            self._count("pms_plane_loads")
            off, nbytes = int(self._pms.index[pid, 0]), int(self._pms.index[pid, 1])
            if nbytes == 0:
                return SparseMetrics.empty(), 64
            sm, _ = SparseMetrics.decode(self._pms_mm[off:off + nbytes])
            return sm, sm.nbytes()

        return self.cache.get_or_load(("pms", pid), load)

    def context_plane(self, ctx: int):
        """Decoded CMS plane for one context: ``(mids, mstart, prof, vals)``."""
        if self._cms is None:
            raise ValueError("database has no CMS store; "
                             "use stripe() which can fall back to a PMS scan")
        ctx = int(ctx)

        def load():
            self._count("cms_plane_loads")
            lo, hi = int(self._cms.offsets[ctx]), int(self._cms.offsets[ctx + 1])
            if lo == hi:
                return empty_plane(), 64
            plane = decode_plane(self._cms_mm[lo:hi])
            return plane, sum(a.nbytes for a in plane)

        return self.cache.get_or_load(("cms", ctx), load)

    def trace(self, pid: int) -> Trace:
        if self._trc is None:
            return Trace.empty()
        pid = int(pid)

        def load():
            self._count("trace_loads")
            tr = self._trc.trace(pid)
            return tr, tr.nbytes()

        return self.cache.get_or_load(("trc", pid), load)

    def _stripe_pushdown(self, ctx: int, mid: int):
        """One stripe decoded straight from the CMS mmap (pushdown read).

        The metric predicate runs against the plane *header* (the
        ``mids``/``mstart`` arrays, tens of bytes), so a context whose plane
        lacks the metric is discarded without materializing it — the cost
        model threshold/call-path selects rely on.  Hits cache only the
        stripe (``("cms-stripe", ctx, mid)``), not the full plane.
        """
        key = ("cms-stripe", ctx, mid)

        def load():
            lo, hi = int(self._cms.offsets[ctx]), int(self._cms.offsets[ctx + 1])
            if lo != hi:
                hit = stripe_from_buffer(self._cms_mm, lo, mid)
                if hit is not None:
                    self._count("cms_stripe_reads")
                    # copy the (small) slices: cached views would pin the
                    # mmap and make close() a BufferError
                    prof, vals = hit[0].copy(), hit[1].copy()
                    return (prof, vals), prof.nbytes + vals.nbytes
            self._count("cms_stripe_skips")
            return (np.empty(0, np.uint32), np.empty(0, np.float64)), 64

        return self.cache.get_or_load(key, load)

    # -- routed queries ------------------------------------------------------
    def stripe(self, ctx: int, metric, *, inclusive: bool = False):
        """Metric ``m`` of context ``c`` across all profiles: one CMS stripe.

        Returns ``(profile_ids, values)``.  A cached full plane is sliced
        for free; otherwise the read is pushed down to the single metric
        (:meth:`_stripe_pushdown`) instead of decoding the whole context
        plane.  Without a CMS store this degrades to the strawman PMS scan
        (counted in ``counters["pms_scan_fallbacks"]``) so PMS-only
        databases stay queryable.
        """
        mid = self.resolve_metric(metric, inclusive=inclusive)
        ctx = int(ctx)
        if self._cms is not None:
            if ("cms", ctx) in self.cache:
                return stripe_from_plane(self.context_plane(ctx), mid)
            return self._stripe_pushdown(ctx, mid)
        self._count("pms_scan_fallbacks")
        pids, vs = [], []
        for pid in range(self.n_profiles):
            v = self.profile_metrics(pid).lookup(int(ctx), mid)
            if v != 0.0:
                pids.append(pid)
                vs.append(v)
        return np.asarray(pids, np.uint32), np.asarray(vs, np.float64)

    def value(self, pid: int, ctx: int, metric, *, inclusive: bool = False) -> float:
        """Point lookup routed to the cheaper store.

        A cached PMS plane always wins (slicing it is free).  Otherwise
        the CMS side pays: since stripe reads push the metric predicate
        down, the miss cost is one plane *header* plus one stripe — always
        bounded above by (and usually far below) decoding the full profile
        plane, so the old decode-the-smaller-plane comparison (paper §3's
        "bytes moved decides") now degenerates to "prefer the stripe".
        PMS-only databases fall back to the profile plane.
        """
        mid = self.resolve_metric(metric, inclusive=inclusive)
        pid, ctx = int(pid), int(ctx)
        if ("pms", pid) in self.cache or self._cms is None:
            return self.profile_metrics(pid).lookup(ctx, mid)
        prof, vals = self.stripe(ctx, mid)
        k = int(np.searchsorted(prof, pid))
        if k < prof.size and prof[k] == pid:
            return float(vals[k])
        return 0.0

    # -- summary statistics (never touch planes) ----------------------------
    def summary(self, ctx: int, metric, stat: str = "sum", *,
                inclusive: bool = False) -> float:
        """Cross-profile summary statistic for one (context, metric).

        Served from the completed database's summary-statistics section
        (paper §4.1.2) — O(log) over the sorted stat keys, zero plane I/O.
        """
        mid = self.resolve_metric(metric, inclusive=inclusive)
        key = pack_keys(np.uint64(ctx), np.uint64(mid))
        k = int(np.searchsorted(self._stat_keys, key))
        if k < self._stat_keys.size and self._stat_keys[k] == key:
            return float(self.stats[stat][k])
        return 0.0

    def metric_entries(self, metric, *, inclusive: bool = False):
        """All summary-stat rows of one metric: ``(ctx_ids, stat_slice_fn)``.

        Returns the context ids carrying this metric and a row-index mask
        into the ``db.stats`` arrays — the building block for threshold
        selects and top-k that never densify.
        """
        mid = self.resolve_metric(metric, inclusive=inclusive)
        mask = self.stats["mid"] == mid
        return self.stats["ctx"][mask], np.flatnonzero(mask)

    # -- lifecycle -----------------------------------------------------------
    def cache_stats(self) -> dict[str, int]:
        return self.cache.stats()

    def close(self) -> None:
        if self._pms_mm is not None:
            self._pms_mm.close()
        if self._cms_mm is not None:
            self._cms_mm.close()
        self._pms.close()
        if self._cms is not None:
            self._cms.close()
        if self._trc is not None:
            self._trc.close()

    def __enter__(self) -> "Database":
        return self

    def __exit__(self, *a) -> None:
        self.close()
