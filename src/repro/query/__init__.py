"""Postmortem query engine over the PMS/CMS/trace analysis databases.

The read path the sparse formats were designed for (paper §3, §4.3):

* :class:`Database` — one handle over a completed run's databases; meta
  parsed once, planes mmap-read on demand, decoded planes LRU-cached, every
  query routed to the cheaper store;
* :mod:`repro.query.select` — call-path predicates, threshold selects,
  top-k hot paths, per-profile / per-context aggregations (never densify);
* :mod:`repro.query.diff` — cross-run regression diffs aligned on the
  unified CCT by call path;
* :mod:`repro.query.timeline` — trace-window and occupancy queries.

Quick start::

    from repro.query import Database, topk_hot_paths, diff

    with Database("runs/db") as db:
        for hp in topk_hot_paths(db, metric=3, k=10):
            print(f"{hp.value:12.3f}  {hp.path}")
"""
from repro.query.cache import LRUCache
from repro.query.database import Database
from repro.query.diff import (DiffEntry, diff, metric_stats_by_path,
                              total_delta)
from repro.query.epoch import EpochSwitcher, wait_for_epoch
from repro.query.export import to_dataframe
from repro.query.select import (HotPath, StripeRow, context_aggregate,
                                profile_aggregate, select_contexts,
                                stripe_select, threshold_contexts,
                                topk_hot_paths)
from repro.query.timeline import activity, occupancy, samples_in_window

__all__ = [
    "Database", "LRUCache", "EpochSwitcher", "wait_for_epoch",
    "HotPath", "StripeRow", "select_contexts", "stripe_select",
    "threshold_contexts", "topk_hot_paths",
    "profile_aggregate", "context_aggregate",
    "DiffEntry", "diff", "metric_stats_by_path", "total_delta",
    "samples_in_window", "occupancy", "activity",
    "to_dataframe",
]
