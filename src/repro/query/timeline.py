"""Trace timeline queries against the integrated trace database.

The trace store (paper §4, footnote 2) holds one (timestamp, context)
sample segment per profile.  Timestamps within a segment are
non-decreasing (the measurement subsystem appends in time order), so a
time window is two binary searches; per-context occupancy over a window is
a segmented count — no window ever materializes samples outside itself.
"""
from __future__ import annotations

import numpy as np

from repro.core.sparse import Trace
from repro.query.database import Database


def samples_in_window(db: Database, pid: int, t0: float, t1: float) -> Trace:
    """Samples of profile ``pid`` with ``t0 <= time < t1``; O(log n) + slice."""
    tr = db.trace(pid)
    lo, hi = np.searchsorted(tr.time, [t0, t1])
    return Trace(tr.time[lo:hi], tr.ctx[lo:hi])


def occupancy(db: Database, t0: float, t1: float, *,
              pids=None) -> tuple[np.ndarray, np.ndarray]:
    """Per-context sample counts inside a window, across profiles.

    Returns ``(ctx_ids, counts)`` sorted by context id.  ``pids`` restricts
    to a subset of profiles (default: all).  Counts approximate per-context
    occupancy under uniform sampling — context c's share of samples is its
    share of wall time.
    """
    pids = range(db.n_profiles) if pids is None else pids
    chunks = []
    for pid in pids:
        win = samples_in_window(db, int(pid), t0, t1)
        if win.ctx.size:
            chunks.append(win.ctx.astype(np.int64))
    if not chunks:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    ctx = np.concatenate(chunks)
    uniq, counts = np.unique(ctx, return_counts=True)
    return uniq, counts


def activity(db: Database, pid: int, t0: float, t1: float,
             n_bins: int = 50) -> np.ndarray:
    """Sample counts of one profile over ``n_bins`` equal time slices —
    the rendering primitive for a trace-view row."""
    win = samples_in_window(db, pid, t0, t1)
    if t1 <= t0:
        return np.zeros(n_bins, np.int64)
    bins = np.clip(((win.time - t0) * n_bins / (t1 - t0)).astype(np.int64),
                   0, n_bins - 1)
    return np.bincount(bins, minlength=n_bins).astype(np.int64)
