"""Call-path and metric-space selection, top-k, and aggregations.

Programmatic call-path query APIs (Hatchet/Chopper-style) over the sparse
stores.  Everything here keeps the paper's space discipline: selections run
on the unified CCT and the summary-statistics section (no plane I/O at
all), per-profile aggregations decode exactly one PMS plane, per-context
aggregations decode exactly one CMS plane — **nothing densifies** the
(profile x context x metric) tensor.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from repro.core.metrics import INCLUSIVE_BIT
from repro.query.database import Database


@dataclass(frozen=True)
class HotPath:
    """One top-k row: a context, its call path, and its costs."""

    ctx: int
    path: str
    value: float          # ranking cost (inclusive or exclusive, see query)
    exclusive: float      # exclusive cost of the same (ctx, metric)

    def as_dict(self) -> dict:
        return {"ctx": self.ctx, "path": self.path,
                "value": self.value, "exclusive": self.exclusive}


# ---------------------------------------------------------------------------
# call-path selection (CCT only — zero store I/O)
# ---------------------------------------------------------------------------

def select_contexts(db: Database, *, kind: int | None = None,
                    name: str | None = None, path_regex: str | None = None,
                    predicate=None) -> np.ndarray:
    """Context ids matching structural filters on the unified CCT.

    ``kind`` matches the node kind, ``name`` the node's own name exactly,
    ``path_regex`` searches the full root-to-node path, and ``predicate``
    is an escape hatch called as ``predicate(ctx, path) -> bool``.  Filters
    compose conjunctively.
    """
    tree = db.tree
    n = db.n_contexts
    keep = np.ones(n, dtype=bool)
    if kind is not None:
        keep &= np.asarray(tree.kind) == int(kind)
    if name is not None:
        names = np.array([tree.name_of(c) for c in range(n)])
        keep &= names == name
    if path_regex is not None or predicate is not None:
        rx = re.compile(path_regex) if path_regex is not None else None
        for c in np.flatnonzero(keep):
            path = tree.full_path(int(c))
            if rx is not None and not rx.search(path):
                keep[c] = False
            elif predicate is not None and not predicate(int(c), path):
                keep[c] = False
    return np.flatnonzero(keep)


def _within_mask(ctx_ids: np.ndarray, within) -> np.ndarray:
    """Membership of ``ctx_ids`` in a ``within`` restriction, which is
    either an array of context ids or a boolean ownership mask indexed by
    context id (the shard fast path: O(n) gather instead of a sort)."""
    w = np.asarray(within)
    if w.dtype == np.bool_:
        return w[ctx_ids.astype(np.int64)]
    return np.isin(ctx_ids, w)


def threshold_contexts(db: Database, metric, *, min_value: float,
                       stat: str = "sum", inclusive: bool = False,
                       within: np.ndarray | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Contexts whose cross-profile ``stat`` of ``metric`` >= ``min_value``.

    Runs entirely on the summary-statistics section (paper §4.1.2); returns
    ``(ctx_ids, stat_values)`` sorted by descending value.  ``within``
    optionally restricts to a prior :func:`select_contexts` result (id
    array or boolean mask over context ids).
    """
    ctx_ids, rows = db.metric_entries(metric, inclusive=inclusive)
    vals = db.stats[stat][rows]
    keep = vals >= min_value
    if within is not None:
        keep &= _within_mask(ctx_ids, within)
    ctx_ids, vals = ctx_ids[keep], vals[keep]
    order = np.lexsort((ctx_ids, -vals))  # value desc, ctx asc tiebreak
    return ctx_ids[order], vals[order]


@dataclass(frozen=True)
class StripeRow:
    """One :func:`stripe_select` row: a selected context with its stripe."""

    ctx: int
    path: str
    stat: float                # the summary stat the context was selected by
    profiles: np.ndarray       # (p,) u32 profile ids carrying the metric
    values: np.ndarray         # (p,) f64 per-profile values


def stripe_select(db: Database, metric, *, min_value: float = 0.0,
                  stat: str = "sum", inclusive: bool = False,
                  kind: int | None = None, name: str | None = None,
                  path_regex: str | None = None, predicate=None,
                  limit: int | None = None) -> list[StripeRow]:
    """Call-path + threshold select that returns per-profile stripes.

    The filters are pushed all the way down: call-path predicates and the
    summary-stat threshold run with zero store I/O (CCT + summary stats),
    and each surviving context is read through the Database's stripe
    pushdown — only the selected metric's slice is decoded, never the full
    CMS plane.  Before the pushdown this shape materialized (and cached)
    one whole plane per selected context just to keep one stripe of it.
    """
    within = None
    if any(f is not None for f in (kind, name, path_regex, predicate)):
        within = select_contexts(db, kind=kind, name=name,
                                 path_regex=path_regex, predicate=predicate)
    ctx_ids, vals = threshold_contexts(db, metric, min_value=min_value,
                                       stat=stat, inclusive=inclusive,
                                       within=within)
    if limit is not None:
        ctx_ids, vals = ctx_ids[:limit], vals[:limit]
    out = []
    for c, v in zip(ctx_ids, vals):
        prof, pv = db.stripe(int(c), metric, inclusive=inclusive)
        out.append(StripeRow(ctx=int(c), path=db.path_of(int(c)),
                             stat=float(v), profiles=prof, values=pv))
    return out


# ---------------------------------------------------------------------------
# top-k hot paths
# ---------------------------------------------------------------------------

def topk_hot_paths(db: Database, metric, k: int = 10, *,
                   inclusive: bool = True, stat: str = "sum",
                   leaves_only: bool = False,
                   within: np.ndarray | None = None) -> list[HotPath]:
    """The k hottest call paths by inclusive (default) or exclusive cost.

    Ranking reads only summary statistics; the deterministic
    ``(-value, ctx)`` order makes results identical across executor
    backends for byte-identical databases.  ``leaves_only`` drops interior
    nodes (whose inclusive cost double-counts their subtrees) — useful for
    flat profiles.  ``within`` restricts ranking to a context subset — id
    array or boolean mask over context ids (how a shard computes its
    partial top-k over only the contexts it owns: the global top-k is a
    merge of per-shard partials because ``within`` sets partition the
    contexts).
    """
    ctx_ids, rows = db.metric_entries(metric, inclusive=inclusive)
    vals = db.stats[stat][rows]
    if within is not None:
        keep = _within_mask(ctx_ids, within)
        ctx_ids, vals = ctx_ids[keep], vals[keep]
    if leaves_only and ctx_ids.size:
        parents = set(int(p) for p in db.tree.parent[1:])
        keep = np.array([int(c) not in parents for c in ctx_ids])
        ctx_ids, vals = ctx_ids[keep], vals[keep]
    order = np.lexsort((ctx_ids, -vals))[:k]
    mid = db.resolve_metric(metric, inclusive=inclusive)
    excl_mid = mid & ~INCLUSIVE_BIT
    out = []
    for i in order:
        c = int(ctx_ids[i])
        out.append(HotPath(ctx=c, path=db.path_of(c), value=float(vals[i]),
                           exclusive=db.summary(c, excl_mid, stat)))
    return out


# ---------------------------------------------------------------------------
# aggregations (one plane each — never densify)
# ---------------------------------------------------------------------------

_AGGS = {
    "sum": np.add.reduceat,
    "max": np.maximum.reduceat,
    "min": np.minimum.reduceat,
}


def profile_aggregate(db: Database, pid: int, *, agg: str = "sum",
                      include_inclusive: bool = False
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Per-metric aggregate over all contexts of one profile.

    One PMS plane read; returns ``(mids, values)`` with metric ids sorted.
    Inclusive-variant metrics are excluded by default (they double-count
    their exclusive sources along every ancestor chain).
    """
    sm = db.profile_metrics(pid)
    _, mids, vals = sm.triplets()
    if not include_inclusive and mids.size:
        keep = (mids & INCLUSIVE_BIT) == 0
        mids, vals = mids[keep], vals[keep]
    if mids.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.float64)
    order = np.argsort(mids, kind="stable")
    mids, vals = mids[order], vals[order]
    bounds = np.flatnonzero(np.diff(mids, prepend=-1))
    return mids[bounds], _AGGS[agg](vals, bounds)


def context_aggregate(db: Database, ctx: int, *, agg: str = "sum"
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Per-metric aggregate across all profiles of one context.

    One CMS plane read; returns ``(mids, values)``.  ``agg="mean"`` divides
    by the number of profiles observing each metric (non-zeros only, the
    same convention as the database's summary mean).
    """
    mids, mstart, _, vals = db.context_plane(ctx)
    if mids.size == 0:
        return np.empty(0, np.int64), np.empty(0, np.float64)
    bounds = mstart[:-1].astype(np.int64)
    if agg == "mean":
        sums = np.add.reduceat(vals, bounds)
        cnts = np.diff(mstart.astype(np.int64))
        return mids.astype(np.int64), sums / np.maximum(cnts, 1)
    return mids.astype(np.int64), _AGGS[agg](vals, bounds)
