"""Hatchet-style dataframe export for interop with pandas tooling.

Automated programmatic analysis frameworks (Hatchet, Chopper) consume
call-path profiles as dataframes: one row per CCT node, one column per
metric, indexed by call path.  :func:`to_dataframe` produces that shape
from a :class:`~repro.query.Database` using the summary-statistics section
alone — zero plane I/O, so exporting a million-context database costs one
pivot, not a store scan.

pandas is an *optional* dependency: importing this module is always safe,
and :func:`to_dataframe` raises a descriptive ``ImportError`` only when
actually called without pandas installed.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import INCLUSIVE_BIT
from repro.query.database import Database


def _pandas():
    try:
        import pandas as pd
    except ImportError as e:  # pragma: no cover - exercised via tests' skip
        raise ImportError(
            "to_dataframe() needs pandas, which is not installed; "
            "`pip install pandas` (the query engine itself does not "
            "require it)") from e
    return pd


def metric_label(db: Database, mid: int) -> str:
    """Human column label for a metric id: registry name when available,
    the numeric id otherwise; ``:I`` marks the propagated inclusive
    variant (mirrors :meth:`Database.resolve_metric`'s name syntax)."""
    if db.registry is not None:
        try:
            return db.registry.name_of(int(mid))
        except KeyError:
            pass
    base = str(int(mid) & ~INCLUSIVE_BIT)
    return base + (":I" if int(mid) & INCLUSIVE_BIT else "")


def to_dataframe(db: Database, *, stat: str = "sum",
                 include_inclusive: bool = True):
    """Export the database's per-context metric summaries as a dataframe.

    One row per context that carries data, indexed by full call path, with
    ``ctx``/``name``/``depth`` structure columns and one column per metric
    holding the cross-profile ``stat`` (inclusive variants as ``<m>:I``
    columns unless ``include_inclusive=False``).  Built entirely from the
    summary-statistics section — no plane reads, see the counters.
    """
    pd = _pandas()
    ctxs = np.asarray(db.stats["ctx"], dtype=np.int64)
    mids = np.asarray(db.stats["mid"], dtype=np.int64)
    vals = np.asarray(db.stats[stat], dtype=np.float64)
    if not include_inclusive:
        keep = (mids & INCLUSIVE_BIT) == 0
        ctxs, mids, vals = ctxs[keep], mids[keep], vals[keep]

    labels = {int(m): metric_label(db, int(m)) for m in np.unique(mids)}
    long = pd.DataFrame({
        "ctx": ctxs,
        "metric": [labels[int(m)] for m in mids],
        "value": vals,
    })
    wide = long.pivot_table(index="ctx", columns="metric", values="value",
                            aggfunc="sum", fill_value=0.0)
    wide.columns.name = None

    tree = db.tree
    parent = np.asarray(tree.parent, dtype=np.int64)
    depth = np.zeros(parent.size, dtype=np.int64)
    for c in range(1, parent.size):       # parents precede children by id
        depth[c] = depth[parent[c]] + 1
    idx = wide.index.to_numpy()
    wide.insert(0, "depth", depth[idx])
    wide.insert(0, "name", [tree.name_of(int(c)) for c in idx])
    wide.insert(0, "ctx", idx)
    wide.index = pd.Index([db.path_of(int(c)) for c in idx], name="path")
    return wide
