"""Byte-budgeted LRU cache for decoded planes and stripes.

The query engine's unit of I/O is a *plane* (one PMS profile plane or one
CMS context plane).  Decoding a plane costs far more than slicing it, so the
:class:`Database` caches decoded planes keyed by ``(store, id)`` and serves
point/stripe queries out of the cached object.

Two properties matter for the serving path (``repro.serve``):

* the cache is thread-safe, so one :class:`~repro.query.Database` can back
  many concurrent requests;
* concurrent misses on the *same* key are coalesced: one loader runs, the
  rest wait for its result — this is the "cache does the batching" behavior
  the serve engine relies on when a burst of requests hits one hot context.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable


class LRUCache:
    """LRU keyed cache bounded by an approximate byte budget.

    ``put`` evicts least-recently-used entries until the budget holds; a
    single value larger than the whole budget is still admitted (and evicted
    by the next insert) so oversized planes degrade to pass-through instead
    of erroring.
    """

    def __init__(self, capacity_bytes: int = 64 << 20):
        self.capacity_bytes = int(capacity_bytes)
        self._entries: OrderedDict[object, tuple[object, int]] = OrderedDict()
        self._bytes = 0
        self._lock = threading.RLock()
        self._inflight: dict[object, threading.Event] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- plain dict-ish surface ---------------------------------------------
    def get(self, key, default=None):
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return default
            self._entries.move_to_end(key)
            self.hits += 1
            return entry[0]

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def put(self, key, value, nbytes: int) -> None:
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
            self._entries[key] = (value, int(nbytes))
            self._bytes += int(nbytes)
            while self._bytes > self.capacity_bytes and len(self._entries) > 1:
                _, (_, sz) = self._entries.popitem(last=False)
                self._bytes -= sz
                self.evictions += 1

    # -- coalescing loader --------------------------------------------------
    def get_or_load(self, key, loader: Callable[[], tuple[object, int]]):
        """Return the cached value for ``key``, loading it at most once.

        ``loader() -> (value, nbytes)`` runs outside the cache lock.  When
        several threads miss the same key simultaneously, one runs the
        loader and the others block on its completion, then re-read the
        cache — a burst of identical queries costs one plane decode.
        """
        while True:
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    return entry[0]
                waiter = self._inflight.get(key)
                if waiter is None:
                    self._inflight[key] = threading.Event()
                    self.misses += 1
                    break
            waiter.wait()
        try:
            value, nbytes = loader()
            self.put(key, value, nbytes)
            return value
        finally:
            with self._lock:
                self._inflight.pop(key).set()

    # -- observability ------------------------------------------------------
    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._bytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "entries": len(self._entries),
                    "bytes": self._bytes}

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0
