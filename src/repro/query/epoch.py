"""Epoch-aware database handles for live snapshot roots.

The ingest tier publishes versioned snapshots (``epoch-N`` directories
behind an atomic ``CURRENT`` pointer — :mod:`repro.ingest.snapshot`); the
query tier follows them **without restart**.  Two pieces:

* :class:`_EpochHandle` — a refcounted wrapper around one open
  :class:`~repro.query.database.Database`.  The serving layer *pins* a
  handle for every in-flight batch (scheduler ``submit_many(pin=...)``),
  so a mid-batch epoch switch can retire the old database but its file
  handles stay open until the last pinned batch resolves — no reply ever
  mixes epochs, and no reader ever hits a closed mmap.
* :class:`EpochSwitcher` — owns the current handle; :meth:`poll` re-reads
  ``CURRENT`` and atomically swings to the new epoch, retiring (not
  closing) the old one.  Losing the race with the publisher's GC raises
  :class:`~repro.ingest.snapshot.SnapshotGone` after one retry against a
  freshly-read pointer.
"""
from __future__ import annotations

import threading
import time

from repro.query.database import Database


class _EpochHandle:
    """Refcounted open database for one epoch.

    Born with one base reference owned by the switcher; every pinned batch
    adds one.  ``retire()`` drops the base reference when a newer epoch
    takes over; the underlying database closes when the last pin releases.
    """

    def __init__(self, db: Database, epoch: int, db_dir: str):
        self.db = db
        self.epoch = int(epoch)
        self.db_dir = str(db_dir)
        self._lock = threading.Lock()
        self._refs = 1
        self._retired = False

    def retain(self) -> "_EpochHandle":
        with self._lock:
            assert self._refs > 0, "retain() after the handle closed"
            self._refs += 1
        return self

    def release(self) -> None:
        with self._lock:
            self._refs -= 1
            close = self._refs == 0
        if close:
            self.db.close()

    def retire(self) -> None:
        """Drop the switcher's base reference (idempotent)."""
        with self._lock:
            if self._retired:
                return
            self._retired = True
        self.release()

    @property
    def refs(self) -> int:
        with self._lock:
            return self._refs


def _read_current(root):
    from repro.ingest.snapshot import read_current
    return read_current(root)


def wait_for_epoch(root, *, timeout_s: float = 60.0, poll_s: float = 0.05,
                   min_epoch: int = 1) -> int:
    """Block until ``root/CURRENT`` points at epoch >= ``min_epoch``;
    returns that epoch.  The bringup helper for serve-before-ingest races
    (a follower can start before the first snapshot publishes)."""
    deadline = time.monotonic() + float(timeout_s)
    while True:
        cur = _read_current(root)
        if cur is not None and cur[0] >= int(min_epoch):
            return cur[0]
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"no snapshot epoch >= {min_epoch} under {root} within "
                f"{timeout_s:.0f}s (is the ingest server publishing?)")
        time.sleep(poll_s)


class EpochSwitcher:
    """Follow a snapshot root's ``CURRENT`` pointer across epochs.

    One instance per serving process.  :meth:`poll` is cheap (one small
    file read) and safe to call from a timer thread; :meth:`acquire`
    returns a retained handle the caller must :meth:`~_EpochHandle.release`.
    """

    def __init__(self, root, *, cache_bytes: int = 64 << 20):
        self.root = str(root)
        self.cache_bytes = int(cache_bytes)
        self._lock = threading.Lock()
        self._handle: _EpochHandle | None = None
        self.transitions = 0
        self.poll()
        if self._handle is None:
            raise FileNotFoundError(
                f"no CURRENT pointer under {self.root}; publish a snapshot "
                f"first or use wait_for_epoch()")

    # -- current state --------------------------------------------------------
    @property
    def epoch(self) -> int | None:
        with self._lock:
            return self._handle.epoch if self._handle is not None else None

    @property
    def db(self) -> Database:
        """Unretained peek at the current database (health/metrics use);
        pin with :meth:`acquire` before serving from it."""
        with self._lock:
            assert self._handle is not None
            return self._handle.db

    def acquire(self) -> _EpochHandle:
        with self._lock:
            assert self._handle is not None, "switcher is closed"
            return self._handle.retain()

    # -- the switch -----------------------------------------------------------
    def _open(self, epoch: int, db_dir: str) -> _EpochHandle:
        from repro.ingest.snapshot import SnapshotGone
        try:
            db = Database(db_dir, cache_bytes=self.cache_bytes)
        except (FileNotFoundError, OSError) as e:
            raise SnapshotGone(f"epoch {epoch} dir vanished: {db_dir}") from e
        db.epoch = int(epoch)
        return _EpochHandle(db, epoch, db_dir)

    def poll(self) -> bool:
        """Re-read ``CURRENT``; switch if it moved.  Returns True on a
        transition.  An open that loses the race with GC retries once
        against a freshly-read pointer before raising ``SnapshotGone``."""
        from repro.ingest.snapshot import SnapshotGone
        cur = _read_current(self.root)
        if cur is None:
            return False
        epoch, db_dir = cur
        with self._lock:
            if self._handle is not None and epoch == self._handle.epoch:
                return False
        try:
            handle = self._open(epoch, db_dir)
        except SnapshotGone:
            cur = _read_current(self.root)
            if cur is None or cur[0] == epoch:
                raise
            handle = self._open(*cur)
        with self._lock:
            old, self._handle = self._handle, handle
            self.transitions += 1
        if old is not None:
            old.retire()
        return True

    def close(self) -> None:
        with self._lock:
            old, self._handle = self._handle, None
        if old is not None:
            old.retire()
