"""Render analysis tables: dry-run/roofline JSONs and database reports.

Dry-run mode (EXPERIMENTS.md §Dry-run / §Roofline)::

    PYTHONPATH=src python -m repro.analysis.report runs/dryrun

Database mode — every table is emitted through the :mod:`repro.query`
engine (summary statistics + routed plane reads), never by hand-rolled
reader loops::

    PYTHONPATH=src python -m repro.analysis.report --db runs/db \
        [--metric 3] [--topk 15] [--diff runs/db_b]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath: str) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b) -> str:
    return f"{b/2**30:.2f}"


def roofline_table(cells: list[dict], mesh: str) -> str:
    rows = [
        "| arch | shape | comp s | mem s | coll s | dominant | MODEL_TF | "
        "useful | MFU bound | mem GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("skipped") or c["mesh"] != mesh:
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | **{r['dominant']}** "
            f"| {r['model_flops']/1e12:.1f} "
            f"| {r['useful_fraction']:.3f} | {r['mfu_bound']:.4f} "
            f"| {fmt_bytes(c['memory']['peak_per_device_bytes'])} "
            f"| {'Y' if c['memory']['fits_16GiB'] else 'N'} |")
    return "\n".join(rows)


def skip_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for c in cells:
        if c.get("skipped") and (c["arch"], c["shape"]) not in seen:
            seen.add((c["arch"], c["shape"]))
            rows.append(f"| {c['arch']} | {c['shape']} | {c['skipped']} |")
    return "\n".join(rows)


def dryrun_table(cells: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | HLO TF/chip | HBM GB/chip | coll GB/chip "
        "| collective mix | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("skipped"):
            continue
        r = c["roofline"]
        mix = ", ".join(f"{k.replace('collective-','c-')}:{v/1e9:.1f}"
                        for k, v in sorted(c["collectives"].items(),
                                           key=lambda kv: -kv[1])[:3])
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['flops_per_chip']/1e12:.2f} "
            f"| {r['hbm_bytes_per_chip']/1e9:.1f} "
            f"| {r['collective_bytes_per_chip']/1e9:.2f} | {mix} "
            f"| {c['timings']['compile_s']:.0f} |")
    return "\n".join(rows)


def summary(cells: list[dict]) -> dict:
    live = [c for c in cells if not c.get("skipped")]
    doms = {}
    for c in live:
        doms[c["roofline"]["dominant"]] = doms.get(c["roofline"]["dominant"], 0) + 1
    fits = sum(c["memory"]["fits_16GiB"] for c in live)
    return {"cells": len(live), "skipped": len(cells) - len(live),
            "dominant": doms, "fits": fits}


# ---------------------------------------------------------------------------
# database reports — every row produced by the query engine
# ---------------------------------------------------------------------------

def _metric_label(db, mid: int) -> str:
    if db.registry is not None:
        try:
            return db.registry.name_of(mid)
        except KeyError:
            pass
    return str(mid)


def hot_paths_table(db, metric, k: int = 10, *, stat: str = "sum") -> str:
    """Top-k call paths by inclusive cost, with exclusive alongside."""
    from repro.query import topk_hot_paths
    rows = [f"| rank | inclusive {stat} | exclusive {stat} | call path |",
            "|---|---|---|---|"]
    for r, hp in enumerate(topk_hot_paths(db, metric, k=k, inclusive=True,
                                          stat=stat), 1):
        rows.append(f"| {r} | {hp.value:.4g} | {hp.exclusive:.4g} "
                    f"| `{hp.path}` |")
    return "\n".join(rows)


def profile_table(db, metric=None) -> str:
    """Per-profile totals: one PMS plane read per row, no densification."""
    from repro.core.metrics import INCLUSIVE_BIT
    from repro.query import profile_aggregate
    mid = db.resolve_metric(metric) if metric is not None else None
    rows = ["| profile | identity | metrics | total |", "|---|---|---|---|"]
    for pid in range(db.n_profiles):
        mids, vals = profile_aggregate(db, pid)
        if mid is not None and mid & INCLUSIVE_BIT:
            # summing an inclusive metric over contexts double-counts every
            # subtree; the per-profile total of an inclusive metric is its
            # value at the root context
            total = float(db.profile_metrics(pid).lookup(0, mid))
        elif mid is not None:
            sel = vals[mids == mid]
            total = float(sel[0]) if sel.size else 0.0
        else:
            total = float(vals.sum())
        ident = db.identity(pid) or {}
        ident_s = ",".join(f"{k}={v}" for k, v in sorted(ident.items()))
        rows.append(f"| {pid} | {ident_s} | {mids.size} | {total:.4g} |")
    return "\n".join(rows)


def findings_table(findings) -> str:
    """Diagnosis findings as a markdown table, most severe first.

    Takes the :class:`~repro.diagnose.Finding` list produced by
    :func:`~repro.diagnose.compute_findings` /
    :func:`~repro.diagnose.regression_findings` (already sorted)."""
    if not findings:
        return "No findings: everything within thresholds and noise bands."
    rows = ["| severity | kind | score | where | message |",
            "|---|---|---|---|---|"]
    for f in findings:
        where = f.path or (f"pid {f.pid}" if f.pid >= 0 else f"ctx {f.ctx}")
        rows.append(f"| {f.severity} | {f.kind} | {f.score:.2f} "
                    f"| `{where}` | {f.message} |")
    return "\n".join(rows)


def diff_table(db_a, db_b, metric, top: int = 10, *, stat: str = "sum") -> str:
    """Cross-run regression table aligned on the unified CCT."""
    from repro.query import diff
    rows = [f"| delta {stat} | A | B | call path |", "|---|---|---|---|"]
    for e in diff(db_a, db_b, metric, stat=stat, top=top):
        rows.append(f"| {e.delta:+.4g} | {e.a:.4g} | {e.b:.4g} "
                    f"| `{e.path}` |")
    return "\n".join(rows)


def database_report(db_dir: str, *, metric=None, k: int = 10,
                    diff_dir: str | None = None) -> str:
    """Full markdown report for one database (optionally diffed vs another)."""
    from repro.core.metrics import INCLUSIVE_BIT
    from repro.query import Database
    sections = []
    with Database(db_dir) as db:
        mids = sorted(set(int(m) for m in db.stats.get("mid", [])
                          if not int(m) & INCLUSIVE_BIT))
        metric = mids[0] if metric is None and mids else metric
        sections.append(f"## Database {db_dir}\n")
        sections.append(json.dumps({
            "profiles": db.n_profiles, "contexts": db.n_contexts,
            "metrics": len(mids), "has_cms": db.has_cms,
            "has_traces": db.has_traces}))
        if metric is not None:
            label = _metric_label(db, db.resolve_metric(metric))
            sections.append(f"\n### Hot paths (metric {label})\n")
            sections.append(hot_paths_table(db, metric, k))
            sections.append("\n### Profiles\n")
            sections.append(profile_table(db, metric))
            if diff_dir is not None:
                with Database(diff_dir) as db_b:
                    sections.append(f"\n### Diff vs {diff_dir}\n")
                    sections.append(diff_table(db, db_b, metric, top=k))
    return "\n".join(sections)


def main():
    ap = argparse.ArgumentParser(prog="repro.analysis.report")
    ap.add_argument("dryrun_dir", nargs="?", default="runs/dryrun")
    ap.add_argument("--db", default=None,
                    help="render a database report instead of dry-run tables")
    ap.add_argument("--metric", default=None)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--diff", default=None,
                    help="second database directory for a cross-run diff")
    args = ap.parse_args()

    if args.db is not None:
        metric = args.metric
        if metric is not None:
            try:
                metric = int(metric)
            except ValueError:
                pass
        print(database_report(args.db, metric=metric, k=args.topk,
                              diff_dir=args.diff))
        return

    cells = load(args.dryrun_dir)
    print("## Summary\n", json.dumps(summary(cells)))
    print("\n## Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(cells, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16, 512 chips)\n")
    print(roofline_table(cells, "2x16x16"))
    print("\n## Skips\n")
    print(skip_table(cells))
    print("\n## Dry-run detail\n")
    print(dryrun_table(cells))


if __name__ == "__main__":
    main()
