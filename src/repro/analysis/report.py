"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.analysis.report runs/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirpath: str) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b) -> str:
    return f"{b/2**30:.2f}"


def roofline_table(cells: list[dict], mesh: str) -> str:
    rows = [
        "| arch | shape | comp s | mem s | coll s | dominant | MODEL_TF | "
        "useful | MFU bound | mem GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("skipped") or c["mesh"] != mesh:
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} "
            f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
            f"| {r['collective_s']:.4g} | **{r['dominant']}** "
            f"| {r['model_flops']/1e12:.1f} "
            f"| {r['useful_fraction']:.3f} | {r['mfu_bound']:.4f} "
            f"| {fmt_bytes(c['memory']['peak_per_device_bytes'])} "
            f"| {'Y' if c['memory']['fits_16GiB'] else 'N'} |")
    return "\n".join(rows)


def skip_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for c in cells:
        if c.get("skipped") and (c["arch"], c["shape"]) not in seen:
            seen.add((c["arch"], c["shape"]))
            rows.append(f"| {c['arch']} | {c['shape']} | {c['skipped']} |")
    return "\n".join(rows)


def dryrun_table(cells: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | HLO TF/chip | HBM GB/chip | coll GB/chip "
        "| collective mix | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("skipped"):
            continue
        r = c["roofline"]
        mix = ", ".join(f"{k.replace('collective-','c-')}:{v/1e9:.1f}"
                        for k, v in sorted(c["collectives"].items(),
                                           key=lambda kv: -kv[1])[:3])
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['flops_per_chip']/1e12:.2f} "
            f"| {r['hbm_bytes_per_chip']/1e9:.1f} "
            f"| {r['collective_bytes_per_chip']/1e9:.2f} | {mix} "
            f"| {c['timings']['compile_s']:.0f} |")
    return "\n".join(rows)


def summary(cells: list[dict]) -> dict:
    live = [c for c in cells if not c.get("skipped")]
    doms = {}
    for c in live:
        doms[c["roofline"]["dominant"]] = doms.get(c["roofline"]["dominant"], 0) + 1
    fits = sum(c["memory"]["fits_16GiB"] for c in live)
    return {"cells": len(live), "skipped": len(cells) - len(live),
            "dominant": doms, "fits": fits}


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun"
    cells = load(d)
    print("## Summary\n", json.dumps(summary(cells)))
    print("\n## Roofline (single-pod 16x16, 256 chips)\n")
    print(roofline_table(cells, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16, 512 chips)\n")
    print(roofline_table(cells, "2x16x16"))
    print("\n## Skips\n")
    print(skip_table(cells))
    print("\n## Dry-run detail\n")
    print(dryrun_table(cells))


if __name__ == "__main__":
    main()
