"""Roofline-term extraction from compiled artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):

    compute term    = HLO_FLOPs / peak_FLOPs_per_chip           [s]
    memory term     = HLO_bytes / HBM_bw_per_chip               [s]
    collective term = collective_wire_bytes / link_bw_per_chip  [s]

``compiled.cost_analysis()`` reports PER-PARTITION flops/bytes under SPMD
(verified empirically), so the terms divide by per-chip peaks directly.
Collective bytes are not in cost_analysis: we parse the compiled HLO and
sum *operand* sizes of every collective op (operand shapes are inline in
post-optimization HLO; where they are not, we derive them from the result
shape and the op's semantics).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*?)\)(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,\s]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)

    @property
    def total(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective instruction."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_shape, opcode, args, rest = m.groups()
        base = next((c for c in _COLLECTIVES if opcode.startswith(c)), None)
        if base is None:
            continue
        op_bytes = _shape_bytes(args)  # operand shapes are inline post-opt
        if op_bytes == 0:
            # derive from result + semantics
            res = _shape_bytes(result_shape)
            gm = _GROUPS_RE.search(rest)
            gsize = len(gm.group(1).split(",")) if gm and gm.group(1).strip() else 1
            if base == "all-gather":
                op_bytes = res // max(gsize, 1)
            elif base == "reduce-scatter":
                op_bytes = res * max(gsize, 1)
            else:
                op_bytes = res
        out[base] = out.get(base, 0) + op_bytes
    return CollectiveStats(out)


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    n_chips: int = 1
    coll_by_kind: dict = field(default_factory=dict)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): compiled-compute usefulness."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-implied MFU upper bound: model flops / (chips x peak x bound time)."""
        denom = self.n_chips * PEAK_FLOPS * self.bound_s
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops, "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "n_chips": self.n_chips,
            "useful_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
        }


def analyze(compiled, *, n_chips: int, model_flops: float = 0.0) -> Roofline:
    """Roofline terms from the trip-count-aware HLO cost model.

    NOTE: ``compiled.cost_analysis()`` counts while-loop bodies once
    (under-reports scan-over-layers by ~L x), so terms come from
    :mod:`repro.analysis.hlo_cost` instead; ``cost_analysis`` is kept in
    the report for reference.
    """
    from repro.analysis import hlo_cost
    cost = hlo_cost.analyze_text(compiled.as_text())
    flops = cost.flops                            # per partition
    hbm = cost.bytes
    coll = cost.coll_bytes
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    rf = Roofline(flops, hbm, coll, compute_s, memory_s, collective_s,
                  dominant, model_flops, n_chips)
    rf.coll_by_kind = dict(cost.coll_by_kind)
    return rf
