"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**,
which under-reports any scan-over-layers program by ~L times (verified
empirically — see tests).  This module re-derives execution costs from the
compiled HLO text with loop awareness:

* **flops** — dots contribute ``2 * result_elems * K`` (K = product of the
  lhs contracting dims); elementwise ops contribute ``result_elems``;
  fused computations are recursed.
* **bytes** — post-fusion HBM traffic model: every *top-level* instruction
  (including fusion ops as single units) moves ``operands + result``
  bytes; intra-fusion values never touch HBM.
* **collective wire bytes** — operand sizes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute.
* **while** — body + condition costs scale by the trip count parsed from
  the loop condition (``compare(iter, constant), direction=LT``);
  ``conditional`` takes the max across branches.

All shapes in post-SPMD compiled HLO are per-partition, so totals are
per-chip — exactly what the roofline terms need.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*?)\)(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->\s*.*\{\s*$")
_ATTR_COMP_RE = {
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DIRECTION_RE = re.compile(r"direction=(\w+)")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _elems_and_bytes(text: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _first_shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    result: str
    opcode: str
    args: str
    rest: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    {n: v * k for n, v in self.coll_by_kind.items()})


_NAME_RE = re.compile(r"%([\w.\-]+)")


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self.shape_of: dict[str, str] = {}   # instr name -> result shape text
        self._parse(hlo_text)
        self._cache: dict[tuple[str, bool], Cost] = {}

    # -- operand resolution (post-scheduling HLO has no inline operand shapes)
    def _operand_shapes(self, args: str) -> str:
        parts = [self.shape_of.get(n, "") for n in _NAME_RE.findall(args)]
        inline = args if _SHAPE_RE.search(args) else ""
        return inline if inline else " ".join(parts)

    def _operand_dims(self, args: str, idx: int = 0) -> list[int]:
        names = _NAME_RE.findall(args)
        if idx < len(names) and names[idx] in self.shape_of:
            return _first_shape_dims(self.shape_of[names[idx]])
        return _first_shape_dims(args)

    # -- parsing ---------------------------------------------------------------
    def _parse(self, text: str):
        cur: str | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            # computation headers start at column 0 and end with '{'
            # (instruction lines are indented; arg lists may nest parens)
            if line and not line[0].isspace() and line.endswith("{") \
                    and "->" in line:
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
                if m:
                    cur = m.group(1)
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    self.computations[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if m:
                ins = Instr(*m.groups())
                self.computations[cur].append(ins)
                self.shape_of[ins.name] = ins.result
        if self.entry is None:
            # fall back: ENTRY marker may appear as 'ENTRY %main.1 (...'
            for name in self.computations:
                if name.startswith("main"):
                    self.entry = name
                    break

    # -- trip counts -------------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Parse `compare(iter, constant(N)), direction=LT` loop bounds."""
        instrs = self.computations.get(cond_comp, [])
        consts: dict[str, int] = {}
        for ins in instrs:
            # constants look like: %c = s32[] constant(28)
            if ins.opcode == "constant":
                m = re.match(r"\s*(\d+)\s*$", ins.args)
                if m:
                    consts[ins.name] = int(m.group(1))
        for ins in instrs:
            if ins.opcode == "compare":
                d = _DIRECTION_RE.search(ins.rest)
                direction = d.group(1) if d else "LT"
                # find an integer constant among the operand names
                for nm, val in consts.items():
                    if nm in ins.args:
                        return val + 1 if direction == "LE" else val
                # inline constant operand: compare(%x, s32[] constant(8))
                m = _CONST_RE.search(ins.args)
                if m:
                    v = int(m.group(1))
                    return v + 1 if direction == "LE" else v
        # the compare may be wrapped in a fusion (kLoop wrapped_compare):
        # the bound constant still lives in this computation — use the max
        # s32 constant as the trip count (standard 0..N-1 counter loops).
        if consts:
            le = False
            for ins in instrs:
                if ins.opcode == "fusion":
                    comp = _ATTR_COMP_RE["calls"].search(ins.rest)
                    if comp:
                        for inner in self.computations.get(comp.group(1), []):
                            if inner.opcode == "compare":
                                d = _DIRECTION_RE.search(inner.rest)
                                le = bool(d and d.group(1) == "LE")
            v = max(consts.values())
            return v + 1 if le else v
        return 1

    # -- instruction costs ----------------------------------------------------------
    def _dot_flops(self, ins: Instr) -> float:
        res_elems, _ = _elems_and_bytes(ins.result)
        lhs_dims = self._operand_dims(ins.args, 0)
        cm = _LHS_CDIMS_RE.search(ins.rest) or _LHS_CDIMS_RE.search(ins.args)
        k = 1
        if cm and lhs_dims:
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    k *= lhs_dims[int(idx)]
        elif lhs_dims:
            k = lhs_dims[-1]  # default: last lhs dim contracts
        return 2.0 * res_elems * k

    def _instr_cost(self, ins: Instr, *, in_fusion: bool) -> Cost:
        if ins.opcode in _SKIP_OPS:
            return Cost()
        c = Cost()
        res_elems, res_bytes = _elems_and_bytes(ins.result)
        # flops
        if ins.opcode == "dot":
            c.flops = self._dot_flops(ins)
        elif ins.opcode == "convolution":
            c.flops = 2.0 * res_elems * max(
                1, int(np_prod(_first_shape_dims(ins.args))
                       / max(res_elems, 1)))
        elif ins.opcode == "fusion":
            comp = _ATTR_COMP_RE["calls"].search(ins.rest)
            if comp:
                inner = self.comp_cost(comp.group(1), in_fusion=True)
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
        elif ins.opcode == "while":
            body = _ATTR_COMP_RE["body"].search(ins.rest)
            cond = _ATTR_COMP_RE["condition"].search(ins.rest)
            trips = self.trip_count(cond.group(1)) if cond else 1
            if body:
                c += self.comp_cost(body.group(1), in_fusion=False).scaled(trips)
            return c  # while's own tuple shuffling ~ free
        elif ins.opcode == "conditional":
            m = _ATTR_COMP_RE["branches"].search(ins.rest)
            if m:
                branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                costs = [self.comp_cost(b, in_fusion=False) for b in branches]
                if costs:
                    c += max(costs, key=lambda x: x.flops)
        elif ins.opcode in ("call", "custom-call", "map", "reduce",
                            "reduce-window", "sort", "scatter", "select-and-scatter"):
            comp = _ATTR_COMP_RE["to_apply"].search(ins.rest)
            c.flops += float(res_elems)
            if ins.opcode == "sort":
                c.flops += float(res_elems) * 10  # ~log n passes
            if comp and ins.opcode == "call":
                c += self.comp_cost(comp.group(1), in_fusion=False)
        else:
            c.flops += float(res_elems)  # elementwise & friends
        # collectives
        base = next((k for k in _COLLECTIVES if ins.opcode.startswith(k)), None)
        if base is not None:
            _, op_bytes = _elems_and_bytes(self._operand_shapes(ins.args))
            if op_bytes == 0:
                op_bytes = res_bytes
            c.coll_bytes += op_bytes
            c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + op_bytes
            c.bytes += op_bytes  # collectives also touch HBM
            return c
        # HBM bytes: only top-level units move memory
        if not in_fusion:
            if ins.opcode == "fusion":
                comp = _ATTR_COMP_RE["calls"].search(ins.rest)
                c.bytes += self._fusion_bytes(
                    ins, comp.group(1) if comp else None, res_bytes)
            else:
                _, op_bytes = _elems_and_bytes(self._operand_shapes(ins.args))
                c.bytes += op_bytes + res_bytes
        return c

    def _fusion_bytes(self, ins: Instr, comp: str | None, res_bytes: int) -> float:
        """HBM bytes for a fusion: slice-aware operand accounting.

        A fused ``dynamic-slice`` reads only its slice and a fused (root)
        ``dynamic-update-slice`` writes only the update region (the rest
        aliases in place) — charging full operand/result arrays inflates
        scan-over-sequence programs by the trip count (measured 20x+ on
        recurrent cells).
        """
        inner = self.computations.get(comp or "", [])
        passthrough = {"bitcast", "reshape", "copy", "transpose"}
        param_of: dict[str, int] = {}
        for i_ins in inner:
            if i_ins.opcode == "parameter":
                m = re.match(r"\s*(\d+)", i_ins.args)
                if m:
                    param_of[i_ins.name] = int(m.group(1))
        # def-use inside the fused computation
        users: dict[str, list[Instr]] = {}
        by_name = {i.name: i for i in inner}
        for i_ins in inner:
            for nm in _NAME_RE.findall(i_ins.args):
                users.setdefault(nm, []).append(i_ins)

        def charge(name: str, full: int, depth=0) -> int:
            """Effective read bytes of a value, following pass-throughs."""
            if depth > 6:
                return full
            out = 0
            for u in users.get(name, []):
                if u.opcode in passthrough:
                    out = max(out, charge(u.name, full, depth + 1))
                elif u.opcode == "dynamic-slice":
                    _, sl = _elems_and_bytes(u.result)
                    out = max(out, sl)
                elif u.opcode == "dynamic-update-slice":
                    args = _NAME_RE.findall(u.args)
                    if args and args[0] == name:
                        out = max(out, 0)      # aliased buffer: no read
                    else:
                        out = max(out, full)   # the update is read fully
                else:
                    return full
            return out

        charged: dict[int, int] = {}
        for pname, pidx in param_of.items():
            _, full = _elems_and_bytes(self.shape_of.get(pname, ""))
            charged[pidx] = charge(pname, full) if users.get(pname) else 0

        # write side: if the fusion root is a dynamic-update-slice the
        # buffer aliases in place and only the update region is written
        root_write = None
        for i_ins in inner:
            if i_ins.opcode == "dynamic-update-slice":
                args = _NAME_RE.findall(i_ins.args)
                upd = 0
                if len(args) > 1:
                    src = args[1]
                    shp = (self.shape_of.get(src, "") if src not in param_of
                           else self.shape_of.get(src, ""))
                    _, upd = _elems_and_bytes(shp or by_name.get(
                        src, Instr("", "", "", "", "")).result)
                root_write = max(root_write or 0, upd)

        total = 0
        arg_names = _NAME_RE.findall(ins.args)
        for pidx, nm in enumerate(arg_names):
            _, full = _elems_and_bytes(self.shape_of.get(nm, ""))
            total += charged.get(pidx, full)
        total += res_bytes if root_write is None else root_write
        return float(total)

    # -- computation costs -------------------------------------------------------
    def comp_cost(self, name: str, *, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in self._cache:
            return self._cache[key]
        total = Cost()
        self._cache[key] = total  # break cycles defensively
        for ins in self.computations.get(name, []):
            total += self._instr_cost(ins, in_fusion=in_fusion)
        return total

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry, in_fusion=False)


def np_prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
