"""Stdlib HTTP transport for the query service.

One ``ThreadingHTTPServer`` in front of one shared
:class:`~repro.query.Database`; connection threads parse/serialize, the
:class:`~repro.serve.scheduler.BatchScheduler` owns execution so requests
from *different* connections coalesce into plane-locality windows.  With
``shards=N`` the execution engine is a
:class:`~repro.serve.shard.ShardedQueryServer` — N worker processes behind
the same transport, consistent-hash routed by plane — which lifts the GIL
ceiling on decode-heavy traffic.

Endpoints::

    POST /v1/query    {"requests": [{...}, ...], "timeout_ms": 5000}
                      -> 200 {"results": [...], "trace_id": "..."}
                      -> 429 + Retry-After when admission control rejects
                      -> 400 on malformed JSON envelopes
    GET  /healthz     liveness + database identity
    GET  /metrics     cache hit/miss/eviction counters, queue depth,
                      admission counters, per-op latency histograms (JSON);
                      ?format=prom renders the same instruments as
                      Prometheus text exposition
    GET  /debug/spans the process flight recorder: recent spans across the
                      whole fleet (workers ship theirs back on replies)
                      plus any frozen worker-death/error dumps

Every call carries a trace id — accepted from an ``X-Trace-Id`` request
header (or a ``trace_id`` envelope field), minted otherwise — stamped on
each request so its spans correlate across scheduler, shard workers, and
replay; the reply echoes it in both body and header.

Payload encoding is :mod:`repro.serve.wire`: a JSON envelope whose array
fields are base64 of the binary on-disk layouts.  ``batching=False`` keeps
the transport but serves each HTTP call directly on its connection thread
— the one-request-at-a-time baseline the load benchmark compares against.
"""
from __future__ import annotations

import json
import math
import threading
from concurrent.futures import TimeoutError as FutureTimeout
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from repro.obs import (MetricsRegistry, configure, mint_trace_id, monotime,
                       recorder, valid_trace_id)
from repro.query.database import Database
from repro.query.epoch import EpochSwitcher, wait_for_epoch
from repro.serve.engine import QueryError, QueryServer
from repro.serve.scheduler import BatchScheduler, Overloaded
from repro.serve.shard import ShardedQueryServer
from repro.serve.warm import warm_cache
from repro.serve.wire import request_from_wire, result_to_wire

MAX_BODY_BYTES = 16 << 20
MAX_REQUESTS_PER_CALL = 1024


class _CappedThreadingHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer with a keep-alive connection cap.

    Every accepted connection holds a handler thread for its whole
    keep-alive lifetime, so an unbounded ThreadingHTTPServer converts a
    connection flood into a thread flood.  With ``max_connections`` set,
    connection number cap+1 is answered with a raw ``429`` +
    ``Retry-After`` and closed *before* a handler thread is spawned —
    the cheapest possible rejection — while established connections are
    unaffected.  ``active``/``rejected`` feed ``/metrics``.
    """

    daemon_threads = True

    def __init__(self, addr, handler, *, max_connections: int = 0):
        self.max_connections = max(0, int(max_connections))
        self.active = 0
        self.rejected = 0
        self._conn_lock = threading.Lock()
        super().__init__(addr, handler)

    def process_request(self, request, client_address):
        if self.max_connections:
            with self._conn_lock:
                if self.active >= self.max_connections:
                    self.rejected += 1
                    reject = True
                else:
                    self.active += 1
                    reject = False
            if reject:
                self._send_reject(request)
                self.close_request(request)
                return
        else:
            with self._conn_lock:
                self.active += 1
        super().process_request(request, client_address)

    @staticmethod
    def _send_reject(request) -> None:
        body = (b'{"error": "TooManyConnections", "retry_after_s": 1, '
                b'"message": "connection cap reached; retry or reuse '
                b'an existing keep-alive connection"}')
        head = ("HTTP/1.1 429 Too Many Requests\r\n"
                "Content-Type: application/json\r\n"
                "Retry-After: 1\r\n"
                f"Content-Length: {len(body)}\r\n"
                "Connection: close\r\n\r\n").encode("ascii")
        try:
            request.sendall(head + body)
        except OSError:
            pass  # client already gone; the close below is all that's left

    def shutdown_request(self, request):
        # end of a connection thread's life (never called for rejects,
        # which close_request directly) — release its cap slot
        try:
            super().shutdown_request(request)
        finally:
            with self._conn_lock:
                self.active = max(0, self.active - 1)

    def handle_error(self, request, client_address):
        # clients hanging up mid-request (resets, broken pipes) are
        # normal churn, not server errors — don't spray tracebacks
        import sys
        exc = sys.exc_info()[1]
        if isinstance(exc, (ConnectionError, TimeoutError)):
            return
        super().handle_error(request, client_address)


class QueryHTTPServer:
    """The serve subsystem, assembled: warm cache, scheduler, transport.

    ``QueryHTTPServer(db).start()`` binds (``port=0`` picks a free port),
    optionally preloads the hottest planes (``warm_bytes``), and serves
    until :meth:`stop`.  Also usable as a context manager.

    ``shards=N`` (N >= 1) swaps the in-process engine for a
    :class:`~repro.serve.shard.ShardedQueryServer`: N worker processes,
    each with its own Database handle and plane cache, consistent-hash
    routed by plane; the scheduler's admission queues and the warming
    budget become per-shard.  ``shards=0`` (default) keeps single-process
    serving.  ``replicas``/``shard_transport``/``hedge_ms`` pass through
    to the sharded engine (R-way ownership, shm vs tcp peer links, hedged
    reads); ``max_connections`` caps concurrent keep-alive connections —
    connection cap+1 gets a pre-thread ``429`` + ``Retry-After``.
    """

    def __init__(self, db, *, host: str = "127.0.0.1",
                 port: int = 0, batching: bool = True, max_batch: int = 16,
                 max_wait_ms: float = 0.0, max_queue: int = 256,
                 executor: str = "threads", n_workers: int = 4,
                 default_timeout_s: float = 30.0, adaptive_wait: bool = True,
                 warm_bytes: int | None = 0, shards: int = 0,
                 shard_cache_bytes: int | None = None,
                 shard_slab_bytes: int = 4 << 20, shard_slabs: int = 8,
                 replicas: int = 2, shard_transport: str = "shm",
                 hedge_ms: float | None = None,
                 max_connections: int = 0,
                 follow: bool = False, poll_ms: float = 250.0,
                 follow_wait_s: float = 60.0,
                 follow_cache_bytes: int = 64 << 20,
                 trace_ring: int | None = None):
        if trace_ring is not None:
            # size (or disable, with 0) this process's flight recorder;
            # the sharded engine below inherits the same capacity for
            # its workers
            configure(trace_ring)
        self.switcher: EpochSwitcher | None = None
        self._poll_s = max(float(poll_ms), 1.0) / 1e3
        if follow:
            # ``db`` is the snapshot ROOT (the ingest tier's output dir),
            # not a Database: open whatever CURRENT points at and track it
            root = str(db)
            wait_for_epoch(root, timeout_s=follow_wait_s)
            self.switcher = EpochSwitcher(root, cache_bytes=follow_cache_bytes)
            self._db = None
        elif isinstance(db, (str, bytes)) or hasattr(db, "__fspath__"):
            raise TypeError("pass an open Database (or follow=True with a "
                            "snapshot root)")
        else:
            self._db = db
        db = self.db  # current Database from here on, either source
        self.shards = max(0, int(shards))
        self.sharded: ShardedQueryServer | None = None
        if self.shards:
            self.sharded = ShardedQueryServer(
                db.db_dir, self.shards,
                cache_bytes=shard_cache_bytes or db.cache.capacity_bytes,
                warm_bytes=warm_bytes, n_slabs=shard_slabs,
                slab_bytes=shard_slab_bytes, replicas=replicas,
                transport=shard_transport, hedge_ms=hedge_ms)
            self.engine = self.sharded
        else:
            self.engine = QueryServer(db)
        self.host, self._port = host, int(port)
        self.batching = bool(batching)
        self.scheduler = BatchScheduler(
            self.engine, max_batch=max_batch, max_wait_ms=max_wait_ms,
            max_queue=max_queue, executor=executor, n_workers=n_workers,
            default_timeout_s=default_timeout_s,
            adaptive_wait=adaptive_wait) if self.batching else None
        self._warm_bytes = warm_bytes
        self.max_connections = max(0, int(max_connections))
        self.warm_report: dict | None = None
        self._draining = False
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._httpd: _CappedThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        self._follower: threading.Thread | None = None
        self._follow_stop = threading.Event()
        self.obs = MetricsRegistry()
        self._reopen_hist = self.obs.histogram("http.epoch_reopen")
        self._http = self.obs.group("http", {"requests": 0})
        self.obs.gauge("http.uptime_s",
                       lambda: max(monotime() - self._started_t, 0.0))
        self.obs.gauge("http.trace_ring_spans",
                       lambda: recorder().recorded)
        self._follow_errors = 0
        self._started_t = 0.0

    @property
    def db(self) -> Database:
        """The database answering *new* calls right now.  Under
        ``follow=True`` this moves when an epoch publishes; in-flight
        batches keep serving their pinned epoch regardless."""
        if self.switcher is not None:
            return self.switcher.db
        return self._db

    # -- epoch following ------------------------------------------------------
    def _follow_loop(self) -> None:
        while not self._follow_stop.wait(self._poll_s):
            try:
                if not self.switcher.poll():
                    continue
                t0 = monotime()
                if self.sharded is not None:
                    # all workers swing together; the window lock inside
                    # reopen() keeps every dispatch single-epoch
                    self.sharded.reopen(self.switcher.db.db_dir)
                else:
                    # in-process: future batches default to the new epoch;
                    # in-flight ones hold pins on the old handle
                    self.engine.db = self.switcher.db
                self._reopen_hist.observe(monotime() - t0)
            except Exception:                               # noqa: BLE001
                # a torn transition (e.g. SnapshotGone racing GC) is
                # retried on the next poll; keep serving the old epoch
                self._follow_errors += 1

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "QueryHTTPServer":
        if self._httpd is not None:
            return self
        if self.sharded is not None:
            # workers warm their own caches for only the planes they own
            self.sharded.start()
            self.warm_report = {"sharded": self.sharded.warm_reports()}
        elif self._warm_bytes is None or self._warm_bytes > 0:
            self.warm_report = warm_cache(self.db, self._warm_bytes or None)
        if self.scheduler is not None:
            self.scheduler.start()
        service = self

        class Handler(_QueryHandler):
            pass

        Handler.service = service
        self._httpd = _CappedThreadingHTTPServer(
            (self.host, self._port), Handler,
            max_connections=self.max_connections)
        self._started_t = monotime()
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        kwargs={"poll_interval": 0.1},
                                        daemon=True, name="serve-http")
        self._thread.start()
        if self.switcher is not None:
            self._follow_stop.clear()
            self._follower = threading.Thread(target=self._follow_loop,
                                              daemon=True,
                                              name="serve-epoch-follower")
            self._follower.start()
        return self

    def drain(self, timeout_s: float = 10.0) -> dict:
        """Graceful shutdown, phase one: stop taking new work, finish
        what's in flight, shed the rest with structured errors.

        New ``/v1/query`` calls are answered ``503 {"error": "Draining"}``
        (a retryable signal — a load balancer or retrying client moves to
        another instance); the accept loop keeps running so those
        rejections are clean HTTP, not connection resets.  Established
        calls get up to ``timeout_s`` to complete.  Returns a report;
        the caller then runs :meth:`stop` for teardown.
        """
        self._draining = True
        t0 = monotime()
        deadline = t0 + max(0.0, float(timeout_s))
        # epoch follower first: no new reopens mid-drain
        self._follow_stop.set()
        if self._follower is not None:
            self._follower.join(timeout=max(deadline - monotime(), 0.1))
        drained = True
        # wait on in-flight *requests*, not connections: idle keep-alive
        # connections are harmless and may outlive any drain window
        while self._inflight > 0:
            if monotime() >= deadline:
                drained = False  # stragglers shed by stop()'s teardown
                break
            threading.Event().wait(0.02)
        return {"drained": drained,
                "waited_s": round(monotime() - t0, 3),
                "inflight_requests": self._inflight,
                "active_connections": (self._httpd.active
                                       if self._httpd is not None else 0)}

    def stop(self) -> None:
        self._follow_stop.set()
        if self._follower is not None:
            self._follower.join(timeout=10.0)
            self._follower = None
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        if self.scheduler is not None:
            self.scheduler.stop()
        if self.sharded is not None:
            self.sharded.close()
        if self.switcher is not None:
            self.switcher.close()

    @property
    def address(self) -> tuple[str, int]:
        assert self._httpd is not None, "server not started"
        return self._httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def __enter__(self) -> "QueryHTTPServer":
        return self.start()

    def __exit__(self, *a) -> None:
        self.stop()

    # -- endpoint bodies ------------------------------------------------------
    def health(self) -> dict:
        out = {"status": "ok", "batching": self.batching,
               "shards": self.shards,
               "profiles": self.db.n_profiles,
               "contexts": self.db.n_contexts,
               "uptime_s": round(monotime() - self._started_t, 3)}
        if self.switcher is not None:
            out["epoch"] = self.switcher.epoch
        return out

    def metrics(self) -> dict:
        out = {"cache": self.db.cache_stats(),
               "db_counters": dict(self.db.counters),
               "http_requests": self._http["requests"],
               "connections": {
                   "cap": self.max_connections,
                   "active": (self._httpd.active
                              if self._httpd is not None else 0),
                   "rejected": (self._httpd.rejected
                                if self._httpd is not None else 0),
                   "draining": self._draining,
               },
               "warm": self.warm_report,
               "uptime_s": round(monotime() - self._started_t, 3)}
        out["scheduler"] = (self.scheduler.metrics()
                            if self.scheduler is not None else None)
        out["shards"] = (self.sharded.metrics()
                         if self.sharded is not None else None)
        if self.switcher is not None:
            out["epoch"] = {"current": self.switcher.epoch,
                            "transitions": self.switcher.transitions,
                            "follow_errors": self._follow_errors,
                            "reopen": self._reopen_hist.as_dict()}
        return out

    def prometheus(self) -> str:
        """Every subsystem's registry, concatenated as one exposition —
        distinct name prefixes (http/db/scheduler/shard) keep the merged
        output collision-free."""
        return MetricsRegistry.render([
            self.obs,
            getattr(self.db, "obs", None),
            self.scheduler.obs if self.scheduler is not None else None,
            self.sharded.obs if self.sharded is not None else None,
        ])

    def debug_spans(self, limit: int = 256) -> dict:
        """The ``GET /debug/spans`` body: this process's flight recorder
        (which includes worker spans shipped back on replies)."""
        return recorder().as_dict(limit=limit)

    def serve_call(self, body: dict, trace_id: str | None = None) -> dict:
        """One ``/v1/query`` call: parse, admit, await, serialize.

        ``trace_id`` (the ``X-Trace-Id`` header) or a ``trace_id``
        envelope field is propagated; anything missing or malformed is
        replaced by a freshly minted id.  Requests that already carry
        their own valid ``trace_id`` keep it.
        """
        call_t0 = monotime()
        tid = trace_id if valid_trace_id(trace_id) else None
        if tid is None:
            env_tid = body.get("trace_id")
            tid = env_tid if valid_trace_id(env_tid) else mint_trace_id()
        raw = body.get("requests")
        if raw is None and "op" in body:
            raw = [body]  # single-request sugar
        if not isinstance(raw, list) or not raw:
            raise _BadRequest("body needs a non-empty 'requests' list")
        if len(raw) > MAX_REQUESTS_PER_CALL:
            raise _CallTooLarge(
                f"at most {MAX_REQUESTS_PER_CALL} requests per call")
        if self.scheduler is not None and len(raw) > self.scheduler.max_queue:
            # could never be admitted: a retrying client would loop forever
            # on 429, so answer non-retryably
            raise _CallTooLarge(
                f"call of {len(raw)} requests exceeds the admission bound "
                f"({self.scheduler.max_queue}); split it")
        timeout_ms = body.get("timeout_ms")
        try:
            timeout_s = (float(timeout_ms) / 1e3 if timeout_ms is not None
                         else None)
        except (TypeError, ValueError):
            raise _BadRequest(
                f"timeout_ms must be a number, got {timeout_ms!r}") from None

        reqs, parse_errors = [], {}
        for i, obj in enumerate(raw):
            try:
                req = request_from_wire(obj)
                if not valid_trace_id(req.trace_id):
                    req.trace_id = tid  # mutable dataclass: stamp in place
                reqs.append(req)
            except (ValueError, TypeError) as e:
                parse_errors[i] = QueryError(
                    op=str(obj.get("op", "?")) if isinstance(obj, dict)
                    else "?", error="BadRequest", message=str(e))
                reqs.append(None)

        live = [r for r in reqs if r is not None]
        # under follow=True, in-process serving pins this call's whole
        # batch to one epoch handle: a concurrent epoch switch retires the
        # old database but these requests keep reading it (the sharded
        # backend instead pins whole dispatch windows inside reopen())
        pin = (self.switcher.acquire()
               if self.switcher is not None and self.sharded is None else None)
        try:
            if self.scheduler is not None:
                futures = iter(self.scheduler.submit_many(
                    live, timeout_s=timeout_s, pin=pin))
                deadline = monotime() + (
                    timeout_s or self.scheduler.default_timeout_s)
                results = []
                for r in reqs:
                    if r is None:
                        results.append(None)
                        continue
                    fut = next(futures)
                    try:
                        results.append(fut.result(
                            timeout=max(deadline - monotime(), 0.0)))
                    except FutureTimeout:
                        results.append(QueryError(
                            op=r.op, error="DeadlineExceeded",
                            message="result wait timed out"))
            else:
                served = iter(self.engine.serve(live, db=pin.db)
                              if pin is not None
                              else self.engine.serve(live))
                results = [None if r is None else next(served) for r in reqs]
        finally:
            if pin is not None:
                pin.release()

        rec = recorder()
        enc_t0 = monotime() if rec.enabled else 0.0
        wire = []
        for i, res in enumerate(results):
            wire.append(result_to_wire(parse_errors[i] if res is None
                                       else res))
        if rec.enabled:
            now = monotime()
            rec.record("encode", "call", enc_t0, now - enc_t0, trace_id=tid,
                       attrs={"n": len(wire)})
            rec.record("request", "call", call_t0, now - call_t0,
                       trace_id=tid, attrs={"n": len(wire)})
        return {"results": wire, "trace_id": tid}


class _BadRequest(ValueError):
    pass


class _CallTooLarge(ValueError):
    """Structurally oversized call: 413, never admissible, do not retry."""


class _QueryHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-serve/1.0"
    service: QueryHTTPServer  # injected per server instance

    def log_message(self, fmt, *args):  # noqa: A003 - stdlib signature
        pass  # keep the serving path quiet; /metrics is the observer

    def _send_json(self, code: int, obj: dict,
                   extra_headers: dict | None = None) -> None:
        payload = json.dumps(obj).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(payload)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(payload)

    def _send_text(self, code: int, text: str, content_type: str) -> None:
        payload = text.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def do_GET(self):  # noqa: N802 - stdlib casing
        svc = self.service
        parts = urlsplit(self.path)
        query = parse_qs(parts.query)
        if parts.path == "/healthz":
            self._send_json(200, svc.health())
        elif parts.path == "/metrics":
            if query.get("format", ["json"])[0] == "prom":
                self._send_text(
                    200, svc.prometheus(),
                    "text/plain; version=0.0.4; charset=utf-8")
            else:
                self._send_json(200, svc.metrics())
        elif parts.path == "/debug/spans":
            try:
                limit = int(query.get("limit", ["256"])[0])
            except ValueError:
                limit = 256
            self._send_json(200, svc.debug_spans(limit=max(1, limit)))
        else:
            self._send_json(404, {"error": "NotFound", "path": self.path})

    def do_POST(self):  # noqa: N802 - stdlib casing
        svc = self.service
        if self.path != "/v1/query":
            self._send_json(404, {"error": "NotFound", "path": self.path})
            return
        if svc._draining:
            # structured shed: a retrying client or LB moves elsewhere;
            # close so the slot frees for the drain to complete
            self.close_connection = True
            self._send_json(503, {"error": "Draining",
                                  "message": "server is draining; retry "
                                             "against another instance"},
                            {"Retry-After": "1", "Connection": "close"})
            return
        svc._http.inc("requests")
        with svc._inflight_lock:
            svc._inflight += 1
        try:
            try:
                n = int(self.headers.get("Content-Length", 0))
            except ValueError:
                n = -1
            if n <= 0 or n > MAX_BODY_BYTES:
                # body never read: the stale bytes would desynchronize the
                # keep-alive stream, so drop the connection with the 400
                self.close_connection = True
                raise _BadRequest(f"Content-Length must be in (0, "
                                  f"{MAX_BODY_BYTES}]")
            body = json.loads(self.rfile.read(n).decode("utf-8"))
            if not isinstance(body, dict):
                raise _BadRequest("body must be a JSON object")
            out = svc.serve_call(body,
                                 trace_id=self.headers.get("X-Trace-Id"))
            self._send_json(200, out,
                            {"X-Trace-Id": out.get("trace_id", "-")})
        except _CallTooLarge as e:
            self._send_json(413, {"error": "CallTooLarge", "message": str(e)})
        except (_BadRequest, json.JSONDecodeError, UnicodeDecodeError) as e:
            self._send_json(400, {"error": "BadRequest", "message": str(e)})
        except Overloaded as e:
            self._send_json(
                429, {"error": "Overloaded",
                      "retry_after_s": e.retry_after_s},
                {"Retry-After": str(max(1, math.ceil(e.retry_after_s)))})
        except Exception as e:  # noqa: BLE001 - last-resort 500
            self._send_json(500, {"error": type(e).__name__, "message": str(e)})
        finally:
            with svc._inflight_lock:
                svc._inflight -= 1
