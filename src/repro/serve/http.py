"""Stdlib HTTP transport for the query service.

One ``ThreadingHTTPServer`` in front of one shared
:class:`~repro.query.Database`; connection threads parse/serialize, the
:class:`~repro.serve.scheduler.BatchScheduler` owns execution so requests
from *different* connections coalesce into plane-locality windows.  With
``shards=N`` the execution engine is a
:class:`~repro.serve.shard.ShardedQueryServer` — N worker processes behind
the same transport, consistent-hash routed by plane — which lifts the GIL
ceiling on decode-heavy traffic.

Endpoints::

    POST /v1/query    {"requests": [{...}, ...], "timeout_ms": 5000}
                      -> 200 {"results": [...], "trace_id": "..."}
                      -> 429 + Retry-After when admission control rejects
                      -> 400 on malformed JSON envelopes
                      ?tenant= (or a "tenant" envelope field) routes to a
                      named tenant on a multi-tenant front; unknown -> 404
    GET  /v1/findings diagnosis findings for a tenant's current epoch
                      (?tenant=, ?metric=, ?inclusive=1, ?analyzers=a,b,
                      ?limit=N) -> {"findings": [...], "count": N};
                      admitted through the tenant's scheduler like any
                      query, so it 429s under that tenant's overload
    GET  /healthz     liveness + database identity
    GET  /metrics     cache hit/miss/eviction counters, queue depth,
                      admission counters, per-op latency histograms (JSON);
                      ?format=prom renders the same instruments as
                      Prometheus text exposition
    GET  /debug/spans the process flight recorder: recent spans across the
                      whole fleet (workers ship theirs back on replies)
                      plus any frozen worker-death/error dumps

Every call carries a trace id — accepted from an ``X-Trace-Id`` request
header (or a ``trace_id`` envelope field), minted otherwise — stamped on
each request so its spans correlate across scheduler, shard workers, and
replay; the reply echoes it in both body and header.

Payload encoding is :mod:`repro.serve.wire`: a JSON envelope whose array
fields are base64 of the binary on-disk layouts.  ``batching=False`` keeps
the transport but serves each HTTP call directly on its connection thread
— the one-request-at-a-time baseline the load benchmark compares against.
"""
from __future__ import annotations

import json
import math
import threading
from concurrent.futures import TimeoutError as FutureTimeout
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from repro.obs import (MetricsRegistry, configure, mint_trace_id, monotime,
                       recorder, valid_trace_id)
from repro.query.database import Database
from repro.query.epoch import EpochSwitcher
from repro.serve.engine import QueryError
from repro.serve.scheduler import BatchScheduler, Overloaded
from repro.serve.shard import ShardedQueryServer
from repro.serve.tenant import TenantBackend
from repro.serve.wire import request_from_wire, result_to_wire

MAX_BODY_BYTES = 16 << 20
MAX_REQUESTS_PER_CALL = 1024

#: tenant name used when the server fronts a single database (the
#: historical mode): requests that name no tenant route here
DEFAULT_TENANT = "default"

#: envelope-only keys of a /v1/query body (everything else in a
#: single-request sugar body is the request itself)
_ENVELOPE_KEYS = ("requests", "timeout_ms", "tenant")


class _CappedThreadingHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer with a keep-alive connection cap.

    Every accepted connection holds a handler thread for its whole
    keep-alive lifetime, so an unbounded ThreadingHTTPServer converts a
    connection flood into a thread flood.  With ``max_connections`` set,
    connection number cap+1 is answered with a raw ``429`` +
    ``Retry-After`` and closed *before* a handler thread is spawned —
    the cheapest possible rejection — while established connections are
    unaffected.  ``active``/``rejected`` feed ``/metrics``.
    """

    daemon_threads = True

    def __init__(self, addr, handler, *, max_connections: int = 0):
        self.max_connections = max(0, int(max_connections))
        self.active = 0
        self.rejected = 0
        self._conn_lock = threading.Lock()
        super().__init__(addr, handler)

    def process_request(self, request, client_address):
        if self.max_connections:
            with self._conn_lock:
                if self.active >= self.max_connections:
                    self.rejected += 1
                    reject = True
                else:
                    self.active += 1
                    reject = False
            if reject:
                self._send_reject(request)
                self.close_request(request)
                return
        else:
            with self._conn_lock:
                self.active += 1
        super().process_request(request, client_address)

    @staticmethod
    def _send_reject(request) -> None:
        body = (b'{"error": "TooManyConnections", "retry_after_s": 1, '
                b'"message": "connection cap reached; retry or reuse '
                b'an existing keep-alive connection"}')
        head = ("HTTP/1.1 429 Too Many Requests\r\n"
                "Content-Type: application/json\r\n"
                "Retry-After: 1\r\n"
                f"Content-Length: {len(body)}\r\n"
                "Connection: close\r\n\r\n").encode("ascii")
        try:
            request.sendall(head + body)
        except OSError:
            pass  # client already gone; the close below is all that's left

    def shutdown_request(self, request):
        # end of a connection thread's life (never called for rejects,
        # which close_request directly) — release its cap slot
        try:
            super().shutdown_request(request)
        finally:
            with self._conn_lock:
                self.active = max(0, self.active - 1)

    def handle_error(self, request, client_address):
        # clients hanging up mid-request (resets, broken pipes) are
        # normal churn, not server errors — don't spray tracebacks
        import sys
        exc = sys.exc_info()[1]
        if isinstance(exc, (ConnectionError, TimeoutError)):
            return
        super().handle_error(request, client_address)


class QueryHTTPServer:
    """The serve subsystem, assembled: warm cache, scheduler, transport.

    ``QueryHTTPServer(db).start()`` binds (``port=0`` picks a free port),
    optionally preloads the hottest planes (``warm_bytes``), and serves
    until :meth:`stop`.  Also usable as a context manager.

    ``shards=N`` (N >= 1) swaps the in-process engine for a
    :class:`~repro.serve.shard.ShardedQueryServer`: N worker processes,
    each with its own Database handle and plane cache, consistent-hash
    routed by plane; the scheduler's admission queues and the warming
    budget become per-shard.  ``shards=0`` (default) keeps single-process
    serving.  ``replicas``/``shard_transport``/``hedge_ms`` pass through
    to the sharded engine (R-way ownership, shm vs tcp peer links, hedged
    reads); ``max_connections`` caps concurrent keep-alive connections —
    connection cap+1 gets a pre-thread ``429`` + ``Retry-After``.

    ``tenants={name: db_or_root, ...}`` (instead of ``db``) serves many
    named databases behind the one listener: each tenant gets its own
    :class:`~repro.serve.tenant.TenantBackend` — engine, scheduler with
    its own admission budget (override per tenant via
    ``tenant_queues={name: N}``), epoch follower — and requests route by
    ``?tenant=`` / the ``"tenant"`` envelope field.  The single-``db``
    form is exactly a one-tenant front named ``"default"``, and the
    historical attribute surface (``srv.db``, ``srv.scheduler``, ...)
    reads through to it.
    """

    def __init__(self, db=None, *, tenants: dict | None = None,
                 tenant_queues: dict | None = None,
                 host: str = "127.0.0.1",
                 port: int = 0, batching: bool = True, max_batch: int = 16,
                 max_wait_ms: float = 0.0, max_queue: int = 256,
                 executor: str = "threads", n_workers: int = 4,
                 default_timeout_s: float = 30.0, adaptive_wait: bool = True,
                 warm_bytes: int | None = 0, shards: int = 0,
                 shard_cache_bytes: int | None = None,
                 shard_slab_bytes: int = 4 << 20, shard_slabs: int = 8,
                 replicas: int = 2, shard_transport: str = "shm",
                 hedge_ms: float | None = None,
                 max_connections: int = 0,
                 follow: bool = False, poll_ms: float = 250.0,
                 follow_wait_s: float = 60.0,
                 follow_cache_bytes: int = 64 << 20,
                 trace_ring: int | None = None):
        if trace_ring is not None:
            # size (or disable, with 0) this process's flight recorder;
            # the sharded engines below inherit the same capacity for
            # their workers
            configure(trace_ring)
        self._poll_s = max(float(poll_ms), 1.0) / 1e3
        backend_kw = dict(
            follow=follow, follow_wait_s=follow_wait_s,
            follow_cache_bytes=follow_cache_bytes, batching=batching,
            max_batch=max_batch, max_wait_ms=max_wait_ms,
            max_queue=max_queue, executor=executor, n_workers=n_workers,
            default_timeout_s=default_timeout_s, adaptive_wait=adaptive_wait,
            warm_bytes=warm_bytes, shards=shards,
            shard_cache_bytes=shard_cache_bytes,
            shard_slab_bytes=shard_slab_bytes, shard_slabs=shard_slabs,
            replicas=replicas, shard_transport=shard_transport,
            hedge_ms=hedge_ms)
        self.tenants: dict[str, TenantBackend] = {}
        if tenants:
            if db is not None:
                raise TypeError("pass either db or tenants=, not both")
            for name, target in tenants.items():
                kw = dict(backend_kw)
                if tenant_queues and name in tenant_queues:
                    kw["max_queue"] = int(tenant_queues[name])
                self.tenants[name] = TenantBackend(name, target, **kw)
        else:
            self.tenants[DEFAULT_TENANT] = TenantBackend(
                DEFAULT_TENANT, db, **backend_kw)
        self._default = next(iter(self.tenants.values()))
        self.multi_tenant = len(self.tenants) > 1
        self.host, self._port = host, int(port)
        self.batching = self._default.batching
        self.max_connections = max(0, int(max_connections))
        self._draining = False
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._httpd: _CappedThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        self._follower: threading.Thread | None = None
        self._follow_stop = threading.Event()
        self.obs = MetricsRegistry()
        self._http = self.obs.group("http", {"requests": 0})
        self.obs.gauge("http.uptime_s",
                       lambda: max(monotime() - self._started_t, 0.0))
        self.obs.gauge("http.trace_ring_spans",
                       lambda: recorder().recorded)
        self._started_t = 0.0

    # -- single-tenant compatibility surface ----------------------------------
    # The historical one-database API (``srv.db``, ``srv.scheduler``, ...)
    # reads through to the *default* backend — the only one in
    # single-tenant mode — so every existing caller keeps working.
    @property
    def db(self) -> Database:
        """The database answering *new* calls right now (default tenant).
        Under ``follow=True`` this moves when an epoch publishes;
        in-flight batches keep serving their pinned epoch regardless."""
        return self._default.db

    @property
    def engine(self):
        return self._default.engine

    @property
    def scheduler(self) -> BatchScheduler | None:
        return self._default.scheduler

    @property
    def sharded(self) -> ShardedQueryServer | None:
        return self._default.sharded

    @property
    def switcher(self) -> EpochSwitcher | None:
        return self._default.switcher

    @property
    def shards(self) -> int:
        return self._default.shards

    @property
    def warm_report(self) -> dict | None:
        return self._default.warm_report

    @property
    def _follow_errors(self) -> int:
        return sum(b.follow_errors for b in self.tenants.values())

    def tenant(self, name: str | None = None) -> TenantBackend:
        """Resolve a tenant name to its backend (``None`` -> default)."""
        if name is None or name == "":
            return self._default
        try:
            return self.tenants[name]
        except KeyError:
            raise _UnknownTenant(
                f"unknown tenant {name!r}; serving "
                f"{sorted(self.tenants)}") from None

    # -- epoch following ------------------------------------------------------
    def _follow_loop(self) -> None:
        while not self._follow_stop.wait(self._poll_s):
            for b in self.tenants.values():
                b.poll_follow()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "QueryHTTPServer":
        if self._httpd is not None:
            return self
        for b in self.tenants.values():
            b.start()
        service = self

        class Handler(_QueryHandler):
            pass

        Handler.service = service
        self._httpd = _CappedThreadingHTTPServer(
            (self.host, self._port), Handler,
            max_connections=self.max_connections)
        self._started_t = monotime()
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        kwargs={"poll_interval": 0.1},
                                        daemon=True, name="serve-http")
        self._thread.start()
        if any(b.switcher is not None for b in self.tenants.values()):
            self._follow_stop.clear()
            self._follower = threading.Thread(target=self._follow_loop,
                                              daemon=True,
                                              name="serve-epoch-follower")
            self._follower.start()
        return self

    def drain(self, timeout_s: float = 10.0) -> dict:
        """Graceful shutdown, phase one: stop taking new work, finish
        what's in flight, shed the rest with structured errors.

        New ``/v1/query`` calls are answered ``503 {"error": "Draining"}``
        (a retryable signal — a load balancer or retrying client moves to
        another instance); the accept loop keeps running so those
        rejections are clean HTTP, not connection resets.  Established
        calls get up to ``timeout_s`` to complete.  Returns a report;
        the caller then runs :meth:`stop` for teardown.
        """
        self._draining = True
        t0 = monotime()
        deadline = t0 + max(0.0, float(timeout_s))
        # epoch follower first: no new reopens mid-drain
        self._follow_stop.set()
        if self._follower is not None:
            self._follower.join(timeout=max(deadline - monotime(), 0.1))
        drained = True
        # wait on in-flight *requests*, not connections: idle keep-alive
        # connections are harmless and may outlive any drain window
        while self._inflight > 0:
            if monotime() >= deadline:
                drained = False  # stragglers shed by stop()'s teardown
                break
            threading.Event().wait(0.02)
        return {"drained": drained,
                "waited_s": round(monotime() - t0, 3),
                "inflight_requests": self._inflight,
                "active_connections": (self._httpd.active
                                       if self._httpd is not None else 0)}

    def stop(self) -> None:
        self._follow_stop.set()
        if self._follower is not None:
            self._follower.join(timeout=10.0)
            self._follower = None
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        for b in self.tenants.values():
            b.stop()

    @property
    def address(self) -> tuple[str, int]:
        assert self._httpd is not None, "server not started"
        return self._httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def __enter__(self) -> "QueryHTTPServer":
        return self.start()

    def __exit__(self, *a) -> None:
        self.stop()

    # -- endpoint bodies ------------------------------------------------------
    def health(self) -> dict:
        out = {"status": "ok", "batching": self.batching,
               "shards": self.shards,
               "profiles": self.db.n_profiles,
               "contexts": self.db.n_contexts,
               "uptime_s": round(monotime() - self._started_t, 3)}
        if self.switcher is not None:
            out["epoch"] = self.switcher.epoch
        if self.multi_tenant:
            out["tenants"] = {name: b.health_fragment()
                              for name, b in self.tenants.items()}
        return out

    def metrics(self) -> dict:
        # the top level keeps the exact historical single-tenant shape
        # (read through to the default backend); multi-tenant fronts add a
        # per-tenant breakdown under "tenants"
        d = self._default
        out = {"cache": d.db.cache_stats(),
               "db_counters": dict(d.db.counters),
               "http_requests": self._http["requests"],
               "connections": {
                   "cap": self.max_connections,
                   "active": (self._httpd.active
                              if self._httpd is not None else 0),
                   "rejected": (self._httpd.rejected
                                if self._httpd is not None else 0),
                   "draining": self._draining,
               },
               "warm": d.warm_report,
               "uptime_s": round(monotime() - self._started_t, 3)}
        frag = d.metrics_fragment()
        out["scheduler"] = frag["scheduler"]
        out["shards"] = frag["shards"]
        if "epoch" in frag:
            out["epoch"] = frag["epoch"]
        if self.multi_tenant:
            out["tenants"] = {name: b.metrics_fragment()
                              for name, b in self.tenants.items()}
        return out

    def prometheus(self) -> str:
        """Every subsystem's registry, concatenated as one exposition —
        distinct name prefixes (http/db/scheduler/shard) keep the merged
        output collision-free.  A multi-tenant front renders each
        backend's registries with a ``tenant="name"`` label so samples
        stay attributable after aggregation."""
        if not self.multi_tenant:
            return MetricsRegistry.render(
                [self.obs] + self._default.registries())
        parts = [self.obs.prometheus()]
        for name, b in self.tenants.items():
            parts.append(MetricsRegistry.render(
                b.registries(), labels=f'tenant="{name}"'))
        return "".join(parts)

    def debug_spans(self, limit: int = 256) -> dict:
        """The ``GET /debug/spans`` body: this process's flight recorder
        (which includes worker spans shipped back on replies)."""
        return recorder().as_dict(limit=limit)

    def serve_call(self, body: dict, trace_id: str | None = None,
                   tenant: str | None = None) -> dict:
        """One ``/v1/query`` call: parse, admit, await, serialize.

        ``trace_id`` (the ``X-Trace-Id`` header) or a ``trace_id``
        envelope field is propagated; anything missing or malformed is
        replaced by a freshly minted id.  Requests that already carry
        their own valid ``trace_id`` keep it.

        ``tenant`` (the ``?tenant=`` query parameter) or a ``tenant``
        envelope field routes the whole call to that tenant's backend —
        its scheduler admits (or 429s) the call against *its own* queue
        budget, so one tenant at its limit cannot shed a neighbor's
        traffic.  Unnamed calls go to the default (first) tenant.
        """
        call_t0 = monotime()
        backend = self.tenant(tenant if tenant else body.get("tenant"))
        tid = trace_id if valid_trace_id(trace_id) else None
        if tid is None:
            env_tid = body.get("trace_id")
            tid = env_tid if valid_trace_id(env_tid) else mint_trace_id()
        raw = body.get("requests")
        if raw is None and "op" in body:
            # single-request sugar: the body IS the request, minus any
            # envelope-only keys riding alongside it
            raw = [{k: v for k, v in body.items()
                    if k not in _ENVELOPE_KEYS}]
        if not isinstance(raw, list) or not raw:
            raise _BadRequest("body needs a non-empty 'requests' list")
        if len(raw) > MAX_REQUESTS_PER_CALL:
            raise _CallTooLarge(
                f"at most {MAX_REQUESTS_PER_CALL} requests per call")
        scheduler = backend.scheduler
        if scheduler is not None and len(raw) > scheduler.max_queue:
            # could never be admitted: a retrying client would loop forever
            # on 429, so answer non-retryably
            raise _CallTooLarge(
                f"call of {len(raw)} requests exceeds the admission bound "
                f"({scheduler.max_queue}); split it")
        timeout_ms = body.get("timeout_ms")
        try:
            timeout_s = (float(timeout_ms) / 1e3 if timeout_ms is not None
                         else None)
        except (TypeError, ValueError):
            raise _BadRequest(
                f"timeout_ms must be a number, got {timeout_ms!r}") from None

        reqs, parse_errors = [], {}
        for i, obj in enumerate(raw):
            try:
                req = request_from_wire(obj)
                if not valid_trace_id(req.trace_id):
                    req.trace_id = tid  # mutable dataclass: stamp in place
                reqs.append(req)
            except (ValueError, TypeError) as e:
                parse_errors[i] = QueryError(
                    op=str(obj.get("op", "?")) if isinstance(obj, dict)
                    else "?", error="BadRequest", message=str(e))
                reqs.append(None)

        live = [r for r in reqs if r is not None]
        # under follow=True, in-process serving pins this call's whole
        # batch to one epoch handle: a concurrent epoch switch retires the
        # old database but these requests keep reading it (the sharded
        # backend instead pins whole dispatch windows inside reopen())
        pin = (backend.switcher.acquire()
               if backend.switcher is not None and backend.sharded is None
               else None)
        try:
            if scheduler is not None:
                futures = iter(scheduler.submit_many(
                    live, timeout_s=timeout_s, pin=pin))
                deadline = monotime() + (
                    timeout_s or scheduler.default_timeout_s)
                results = []
                for r in reqs:
                    if r is None:
                        results.append(None)
                        continue
                    fut = next(futures)
                    try:
                        results.append(fut.result(
                            timeout=max(deadline - monotime(), 0.0)))
                    except FutureTimeout:
                        results.append(QueryError(
                            op=r.op, error="DeadlineExceeded",
                            message="result wait timed out"))
            else:
                engine = backend.engine
                served = iter(engine.serve(live, db=pin.db)
                              if pin is not None
                              else engine.serve(live))
                results = [None if r is None else next(served) for r in reqs]
        finally:
            if pin is not None:
                pin.release()

        rec = recorder()
        enc_t0 = monotime() if rec.enabled else 0.0
        wire = []
        for i, res in enumerate(results):
            wire.append(result_to_wire(parse_errors[i] if res is None
                                       else res))
        if rec.enabled:
            now = monotime()
            rec.record("encode", "call", enc_t0, now - enc_t0, trace_id=tid,
                       attrs={"n": len(wire)})
            rec.record("request", "call", call_t0, now - call_t0,
                       trace_id=tid, attrs={"n": len(wire)})
        out = {"results": wire, "trace_id": tid}
        if self.multi_tenant:
            out["tenant"] = backend.name
        return out

    def findings_call(self, query: dict, trace_id: str | None = None) -> dict:
        """The ``GET /v1/findings`` body: run the diagnosis analyzers on a
        tenant's current epoch through the normal admission path.

        ``query`` holds flat string query parameters: ``tenant``,
        ``metric`` (id or name), ``inclusive`` (0/1), ``analyzers``
        (comma-separated), ``limit``.  Delegates to :meth:`serve_call`, so
        admission (429), epoch pinning, and tracing behave exactly like a
        POSTed ``findings`` op.
        """
        known = {"tenant", "metric", "inclusive", "analyzers", "limit"}
        unknown = set(query) - known
        if unknown:
            raise _BadRequest(f"unknown query parameters {sorted(unknown)}; "
                              f"known: {sorted(known)}")
        req: dict = {"op": "findings"}
        metric = query.get("metric")
        if metric is not None:
            req["metric"] = (int(metric) if metric.lstrip("-").isdigit()
                             else metric)
        if query.get("inclusive", "") in ("1", "true", "yes"):
            req["inclusive"] = True
        params: dict = {}
        if "analyzers" in query:
            params["analyzers"] = [a for a in query["analyzers"].split(",")
                                   if a]
        if "limit" in query:
            try:
                params["limit"] = int(query["limit"])
            except ValueError:
                raise _BadRequest(
                    f"limit must be an integer, got "
                    f"{query['limit']!r}") from None
        if params:
            req["params"] = params
        out = self.serve_call({"requests": [req]}, trace_id=trace_id,
                              tenant=query.get("tenant"))
        res = out["results"][0]
        if res.get("kind") == "error":
            # analyzer/metric parameter problems surface as per-request
            # errors; for this single-request endpoint they are the
            # caller's fault -> 400
            if res.get("error") in ("ValueError", "KeyError", "BadRequest"):
                raise _BadRequest(res.get("message", "bad findings request"))
            raise RuntimeError(
                f"{res.get('error')}: {res.get('message', '')}")
        body = {"findings": res.get("rows", []), "trace_id": out["trace_id"]}
        body["count"] = len(body["findings"])
        if "tenant" in out:
            body["tenant"] = out["tenant"]
        return body


class _BadRequest(ValueError):
    pass


class _UnknownTenant(ValueError):
    """Named tenant is not served here: 404, routing error, do not retry."""


class _CallTooLarge(ValueError):
    """Structurally oversized call: 413, never admissible, do not retry."""


class _QueryHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-serve/1.0"
    service: QueryHTTPServer  # injected per server instance

    def log_message(self, fmt, *args):  # noqa: A003 - stdlib signature
        pass  # keep the serving path quiet; /metrics is the observer

    def _send_json(self, code: int, obj: dict,
                   extra_headers: dict | None = None) -> None:
        payload = json.dumps(obj).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(payload)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(payload)

    def _send_text(self, code: int, text: str, content_type: str) -> None:
        payload = text.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def do_GET(self):  # noqa: N802 - stdlib casing
        svc = self.service
        parts = urlsplit(self.path)
        query = parse_qs(parts.query)
        if parts.path == "/healthz":
            self._send_json(200, svc.health())
        elif parts.path == "/metrics":
            if query.get("format", ["json"])[0] == "prom":
                self._send_text(
                    200, svc.prometheus(),
                    "text/plain; version=0.0.4; charset=utf-8")
            else:
                self._send_json(200, svc.metrics())
        elif parts.path == "/debug/spans":
            try:
                limit = int(query.get("limit", ["256"])[0])
            except ValueError:
                limit = 256
            self._send_json(200, svc.debug_spans(limit=max(1, limit)))
        elif parts.path == "/v1/findings":
            if svc._draining:
                self.close_connection = True
                self._send_json(503, {"error": "Draining",
                                      "message": "server is draining; retry "
                                                 "against another instance"},
                                {"Retry-After": "1", "Connection": "close"})
                return
            svc._http.inc("requests")
            with svc._inflight_lock:
                svc._inflight += 1
            try:
                flat = {k: v[0] for k, v in query.items()}
                out = svc.findings_call(
                    flat, trace_id=self.headers.get("X-Trace-Id"))
                self._send_json(200, out,
                                {"X-Trace-Id": out.get("trace_id", "-")})
            except _UnknownTenant as e:
                self._send_json(404, {"error": "UnknownTenant",
                                      "message": str(e)})
            except _BadRequest as e:
                self._send_json(400, {"error": "BadRequest",
                                      "message": str(e)})
            except Overloaded as e:
                self._send_json(
                    429, {"error": "Overloaded",
                          "retry_after_s": e.retry_after_s},
                    {"Retry-After": str(max(1, math.ceil(e.retry_after_s)))})
            except Exception as e:  # noqa: BLE001 - last-resort 500
                self._send_json(500, {"error": type(e).__name__,
                                      "message": str(e)})
            finally:
                with svc._inflight_lock:
                    svc._inflight -= 1
        else:
            self._send_json(404, {"error": "NotFound", "path": self.path})

    def do_POST(self):  # noqa: N802 - stdlib casing
        svc = self.service
        parts = urlsplit(self.path)
        if parts.path != "/v1/query":
            self._send_json(404, {"error": "NotFound", "path": self.path})
            return
        tenant = parse_qs(parts.query).get("tenant", [None])[0]
        if svc._draining:
            # structured shed: a retrying client or LB moves elsewhere;
            # close so the slot frees for the drain to complete
            self.close_connection = True
            self._send_json(503, {"error": "Draining",
                                  "message": "server is draining; retry "
                                             "against another instance"},
                            {"Retry-After": "1", "Connection": "close"})
            return
        svc._http.inc("requests")
        with svc._inflight_lock:
            svc._inflight += 1
        try:
            try:
                n = int(self.headers.get("Content-Length", 0))
            except ValueError:
                n = -1
            if n <= 0 or n > MAX_BODY_BYTES:
                # body never read: the stale bytes would desynchronize the
                # keep-alive stream, so drop the connection with the 400
                self.close_connection = True
                raise _BadRequest(f"Content-Length must be in (0, "
                                  f"{MAX_BODY_BYTES}]")
            body = json.loads(self.rfile.read(n).decode("utf-8"))
            if not isinstance(body, dict):
                raise _BadRequest("body must be a JSON object")
            out = svc.serve_call(body,
                                 trace_id=self.headers.get("X-Trace-Id"),
                                 tenant=tenant)
            self._send_json(200, out,
                            {"X-Trace-Id": out.get("trace_id", "-")})
        except _UnknownTenant as e:
            self._send_json(404, {"error": "UnknownTenant", "message": str(e)})
        except _CallTooLarge as e:
            self._send_json(413, {"error": "CallTooLarge", "message": str(e)})
        except (_BadRequest, json.JSONDecodeError, UnicodeDecodeError) as e:
            self._send_json(400, {"error": "BadRequest", "message": str(e)})
        except Overloaded as e:
            self._send_json(
                429, {"error": "Overloaded",
                      "retry_after_s": e.retry_after_s},
                {"Retry-After": str(max(1, math.ceil(e.retry_after_s)))})
        except Exception as e:  # noqa: BLE001 - last-resort 500
            self._send_json(500, {"error": type(e).__name__, "message": str(e)})
        finally:
            with svc._inflight_lock:
                svc._inflight -= 1
