"""Typed stdlib client for the query service HTTP transport.

One :class:`QueryClient` wraps one keep-alive ``http.client`` connection
(HTTP/1.1), decodes the :mod:`repro.serve.wire` payloads back into the
same types :meth:`QueryServer.submit` returns locally (``SparseMetrics``,
``(profiles, values)`` arrays, ``HotPath`` rows, ``Trace`` windows), and
maps transport-level failures to typed exceptions:

* :class:`ServerOverloaded` — admission control said 429; carries the
  server's ``Retry-After`` hint;
* :class:`RequestFailed` — a single-op convenience call resolved to a
  structured :class:`~repro.serve.engine.QueryError` (batch calls return
  the error objects inline instead, preserving slot alignment).

:class:`RetryPolicy` (and :meth:`QueryClient.batch_with_retry`) adds
client-side retry-with-backoff: 429s honor the server's ``Retry-After``
hint, transient transport failures back off exponentially with full
jitter, non-retryable 4xx fail fast, and a retry budget bounds the total
time spent.

Not thread-safe: it is one socket.  Give each load-generator client its
own instance (they are cheap) — exactly what ``benchmarks/serve_load.py``
does.
"""
from __future__ import annotations

import http.client
import json
import random
import time
from dataclasses import dataclass, field
from urllib.parse import urlencode

from repro.diagnose.findings import Finding
from repro.serve.engine import QueryError, QueryRequest
from repro.serve.wire import request_to_wire, result_from_wire


class ServerOverloaded(RuntimeError):
    def __init__(self, retry_after_s: float):
        super().__init__(f"server overloaded; retry after {retry_after_s:.2f}s")
        self.retry_after_s = float(retry_after_s)


class RequestFailed(RuntimeError):
    def __init__(self, err: QueryError):
        super().__init__(f"{err.error}: {err.message} (op={err.op})")
        self.query_error = err


class TransportError(RuntimeError):
    """Non-2xx/429 responses: 400 envelopes, 500s, unreachable paths."""

    def __init__(self, status: int, body: dict):
        super().__init__(f"HTTP {status}: {body}")
        self.status, self.body = status, body


class RetryBudgetExceeded(RuntimeError):
    """The retry policy ran out of budget/attempts; carries the last
    transport-level failure as ``__cause__``."""


@dataclass
class RetryPolicy:
    """Retry-with-backoff for transient service failures.

    * **what retries**: 429 (:class:`ServerOverloaded` — honoring the
      server's ``Retry-After`` hint as a floor), 5xx responses, and socket
      -level :class:`OSError`/``http.client`` failures (server restarting);
    * **what fails fast**: every other 4xx (:class:`TransportError` with
      ``400 <= status < 500``) — the request is structurally wrong and
      will never succeed, so retrying would loop forever on e.g. a 413;
    * **backoff**: exponential from ``base_s`` capped at ``max_backoff_s``
      with full jitter (``uniform(0, wait)``) so a herd of clients bounced
      by the same overload spike does not re-arrive in lockstep;
    * **budget**: total time spent (including the next planned sleep) is
      bounded by ``budget_s`` and attempts by ``max_attempts`` — whichever
      runs out first raises :class:`RetryBudgetExceeded` from the last
      failure.
    """

    max_attempts: int = 6
    budget_s: float = 30.0
    base_s: float = 0.05
    max_backoff_s: float = 2.0
    jitter: bool = True
    rng: random.Random = field(default_factory=random.Random)

    def backoff_s(self, attempt: int, retry_after_s: float = 0.0) -> float:
        """Sleep before retry ``attempt`` (0-based), >= the server hint."""
        wait = min(self.base_s * (2 ** attempt), self.max_backoff_s)
        if self.jitter:
            wait = self.rng.uniform(0.0, wait)
        return max(wait, float(retry_after_s))

    def call(self, fn, *, sleep=time.sleep):
        """Run ``fn()`` under this policy; returns its result."""
        t0 = time.monotonic()
        last: Exception | None = None
        for attempt in range(max(1, self.max_attempts)):
            try:
                return fn()
            except ServerOverloaded as e:
                last, hint = e, e.retry_after_s
            except TransportError as e:
                if 400 <= e.status < 500:
                    raise  # non-retryable: the request itself is wrong
                last, hint = e, 0.0
            except (http.client.HTTPException, ConnectionError, OSError) as e:
                last, hint = e, 0.0
            wait = self.backoff_s(attempt, hint)
            if (attempt + 1 >= self.max_attempts
                    or time.monotonic() - t0 + wait > self.budget_s):
                break
            sleep(wait)
        raise RetryBudgetExceeded(
            f"gave up after {attempt + 1} attempt(s) / "
            f"{time.monotonic() - t0:.2f}s") from last


class JSONClient:
    """One keep-alive HTTP/1.1 connection speaking JSON envelopes.

    The shared transport under :class:`QueryClient` and the ingest tier's
    :class:`~repro.ingest.client.IngestClient`: JSON (or raw-bytes) request
    bodies, JSON responses, 429 -> :class:`ServerOverloaded` (so one
    :class:`RetryPolicy` serves both services), everything else non-200 ->
    :class:`TransportError`.
    """

    def __init__(self, host: str, port: int, *, timeout_s: float = 30.0):
        self.host, self.port = host, int(port)
        self.timeout_s = float(timeout_s)
        self._conn: http.client.HTTPConnection | None = None

    # -- transport ------------------------------------------------------------
    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout_s)
        return self._conn

    def _roundtrip(self, method: str, path: str, body: dict | None = None,
                   *, raw: bytes | None = None,
                   content_type: str = "application/json",
                   headers: dict | None = None):
        if raw is not None:
            payload: bytes | None = raw
        else:
            payload = (None if body is None
                       else json.dumps(body).encode("utf-8"))
        hdrs = {"Content-Type": content_type} if payload is not None else {}
        if headers:
            hdrs.update(headers)
        headers = hdrs
        for attempt in (0, 1):  # one transparent retry on a dropped keep-alive
            conn = self._connection()
            try:
                conn.request(method, path, body=payload, headers=headers)
                resp = conn.getresponse()
                data = resp.read()
                break
            except (http.client.HTTPException, ConnectionError, OSError):
                self.close()
                if attempt:
                    raise
        obj = json.loads(data.decode("utf-8")) if data else {}
        if resp.status == 429:
            retry = float(obj.get("retry_after_s")
                          or resp.headers.get("Retry-After") or 1.0)
            raise ServerOverloaded(retry)
        if resp.status != 200:
            raise TransportError(resp.status, obj)
        return obj

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self):
        return self

    def __exit__(self, *a) -> None:
        self.close()


class QueryClient(JSONClient):
    """``tenant=`` pins every call this client makes to one named tenant
    on a multi-tenant front (sent as the ``tenant`` envelope field /
    ``?tenant=`` query parameter); ``None`` keeps the historical
    default-tenant behavior."""

    def __init__(self, host: str, port: int, *, timeout_s: float = 30.0,
                 tenant: str | None = None):
        super().__init__(host, port, timeout_s=timeout_s)
        self.tenant = tenant

    # -- batched query surface -------------------------------------------------
    def batch(self, requests: list[QueryRequest], *,
              timeout_ms: float | None = None,
              trace_id: str | None = None) -> list:
        """Submit a batch; returns one decoded result per slot (failures as
        inline :class:`QueryError` objects, never exceptions).  ``trace_id``
        is sent as ``X-Trace-Id`` so the server stamps the caller's id on
        every span instead of minting its own."""
        body: dict = {"requests": [request_to_wire(r) for r in requests]}
        if timeout_ms is not None:
            body["timeout_ms"] = timeout_ms
        if self.tenant is not None:
            body["tenant"] = self.tenant
        hdrs = {"X-Trace-Id": trace_id} if trace_id else None
        obj = self._roundtrip("POST", "/v1/query", body, headers=hdrs)
        self.last_trace_id = obj.get("trace_id")
        return [result_from_wire(r) for r in obj["results"]]

    def batch_with_retry(self, requests: list[QueryRequest], *,
                         policy: RetryPolicy | None = None,
                         timeout_ms: float | None = None,
                         trace_id: str | None = None,
                         sleep=time.sleep) -> list:
        """:meth:`batch` wrapped in a :class:`RetryPolicy` (default policy
        when none given): transparently rides out 429 bursts and server
        restarts, fails fast on non-retryable 4xx."""
        policy = policy or RetryPolicy()
        return policy.call(
            lambda: self.batch(requests, timeout_ms=timeout_ms,
                               trace_id=trace_id), sleep=sleep)

    def _one(self, req: QueryRequest):
        res = self.batch([req])[0]
        if isinstance(res, QueryError):
            raise RequestFailed(res)
        return res

    # -- typed convenience ops -------------------------------------------------
    def profile(self, pid: int):
        return self._one(QueryRequest(op="profile", pid=pid))

    def stripe(self, ctx: int, metric, *, inclusive: bool = False):
        return self._one(QueryRequest(op="stripe", ctx=ctx, metric=metric,
                                      inclusive=inclusive))

    def value(self, pid: int, ctx: int, metric, *,
              inclusive: bool = False) -> float:
        return self._one(QueryRequest(op="value", pid=pid, ctx=ctx,
                                      metric=metric, inclusive=inclusive))

    def topk(self, metric, *, k: int = 10, inclusive: bool = True,
             **params):
        return self._one(QueryRequest(op="topk", metric=metric, k=k,
                                      inclusive=inclusive, params=params))

    def window(self, pid: int, t0: float, t1: float):
        return self._one(QueryRequest(op="window", pid=pid, t0=t0, t1=t1))

    def findings(self, *, metric=None, inclusive: bool = False,
                 analyzers=None, limit: int = 0,
                 trace_id: str | None = None) -> list:
        """Run the diagnosis analyzers server-side (``GET /v1/findings``)
        and return typed :class:`~repro.diagnose.Finding` records, most
        severe first.  ``analyzers`` limits the pass (e.g.
        ``("imbalance",)``); default runs the full trace-derived set."""
        q: dict = {}
        if self.tenant is not None:
            q["tenant"] = self.tenant
        if metric is not None:
            q["metric"] = metric
        if inclusive:
            q["inclusive"] = "1"
        if analyzers:
            q["analyzers"] = ",".join(analyzers)
        if limit:
            q["limit"] = int(limit)
        path = "/v1/findings" + (f"?{urlencode(q)}" if q else "")
        hdrs = {"X-Trace-Id": trace_id} if trace_id else None
        obj = self._roundtrip("GET", path, headers=hdrs)
        self.last_trace_id = obj.get("trace_id")
        return [Finding.from_dict(row) for row in obj.get("findings", [])]

    # -- service introspection --------------------------------------------------
    def health(self) -> dict:
        return self._roundtrip("GET", "/healthz")

    def metrics(self) -> dict:
        return self._roundtrip("GET", "/metrics")
