"""Wire protocol for the query service: JSON envelope, binary payloads.

The transport is JSON (debuggable with curl, stdlib-only on both sides),
but the *data* stays binary: numpy arrays and whole profile planes travel
as base64 of the exact on-disk :mod:`repro.utils.binio` array blocks /
:meth:`SparseMetrics.encode` layout — the same bytes the stores hold, so
serialization costs one base64 pass, never a float->decimal->float trip
(which would be both slow and lossy for f64 metric values).

Shapes on the wire (``result_to_wire`` / ``result_from_wire``):

===========  =============================================================
kind         payload
===========  =============================================================
``profile``  ``data``: b64(SparseMetrics.encode()) — one binary plane
``stripe``   ``profiles``/``values``: binary arrays
``value``    ``value``: JSON float (scalars are fine as text)
``topk``     ``rows``: list of HotPath dicts
``window``   ``time``/``ctx``: binary arrays
``findings`` ``rows``: list of Finding dicts (diagnosis records)
``error``    ``op``/``error``/``message`` — structured per-request failure
===========  =============================================================

An *empty* findings list encodes as ``topk`` (the all-HotPath check is
vacuously true first); both decode to ``[]``, so the ambiguity is
value-preserving.
"""
from __future__ import annotations

import base64
from dataclasses import MISSING, fields

import numpy as np

from repro.core.sparse import SparseMetrics, Trace
from repro.diagnose.findings import Finding
from repro.query.select import HotPath
from repro.serve.engine import QueryError, QueryRequest
from repro.utils import binio

_REQUEST_FIELDS = {f.name for f in fields(QueryRequest)}


# -- binary array payloads ---------------------------------------------------

def nd_to_wire(arr: np.ndarray) -> dict:
    raw = binio.pack_array(np.ascontiguousarray(arr))
    return {"__nd__": base64.b64encode(raw).decode("ascii")}


def wire_to_nd(obj: dict) -> np.ndarray:
    arr, _ = binio.unpack_array(base64.b64decode(obj["__nd__"]))
    return arr


# -- requests ----------------------------------------------------------------

def request_to_wire(req: QueryRequest) -> dict:
    """Encode a request sparsely: ``op`` plus every non-default field (the
    decoder fills defaults back in, so unknown future ops keep working).

    ``trace_id`` rides this envelope like any other field: clients that
    set it (or the HTTP edge, which mints one per request) get the same id
    stamped on every span the request produces — in-process, in shard
    workers, and through replay-after-death — with zero wire cost for
    untraced requests (default ``None`` is elided like every default)."""
    out: dict = {"op": req.op}
    for f in fields(QueryRequest):
        if f.name == "op":
            continue
        v = getattr(req, f.name)
        default = f.default_factory() if f.default_factory is not MISSING \
            else f.default
        if v != default:
            out[f.name] = v
    return out


def request_from_wire(obj: dict) -> QueryRequest:
    """Build a :class:`QueryRequest` from an untrusted wire dict.

    Raises ``ValueError`` on structural problems (not a dict, missing
    ``op``, unknown fields) — the server maps that to a per-request error
    entry, never a dropped batch.
    """
    if not isinstance(obj, dict):
        raise ValueError(f"request must be a JSON object, got {type(obj).__name__}")
    unknown = set(obj) - _REQUEST_FIELDS
    if unknown:
        raise ValueError(f"unknown request fields {sorted(unknown)}")
    if not isinstance(obj.get("op"), str):
        raise ValueError("request needs a string 'op'")
    return QueryRequest(**obj)


# -- results -----------------------------------------------------------------

def result_to_wire(res) -> dict:
    if isinstance(res, QueryError):
        return {"kind": "error", **res.as_dict()}
    if isinstance(res, SparseMetrics):
        return {"kind": "profile",
                "data": base64.b64encode(res.encode()).decode("ascii")}
    if isinstance(res, Trace):
        return {"kind": "window", "time": nd_to_wire(res.time),
                "ctx": nd_to_wire(res.ctx)}
    if isinstance(res, list) and res and \
            all(isinstance(f, Finding) for f in res):
        return {"kind": "findings", "rows": [f.as_dict() for f in res]}
    if isinstance(res, list) and all(isinstance(h, HotPath) for h in res):
        return {"kind": "topk", "rows": [h.as_dict() for h in res]}
    if isinstance(res, tuple) and len(res) == 2:
        prof, vals = res
        return {"kind": "stripe", "profiles": nd_to_wire(np.asarray(prof)),
                "values": nd_to_wire(np.asarray(vals))}
    if isinstance(res, (int, float, np.floating, np.integer)):
        return {"kind": "value", "value": float(res)}
    raise TypeError(f"unserializable result type {type(res).__name__}")


def result_from_wire(obj: dict):
    kind = obj.get("kind")
    if kind == "error":
        return QueryError(op=obj.get("op", "?"), error=obj.get("error", "?"),
                          message=obj.get("message", ""))
    if kind == "profile":
        sm, _ = SparseMetrics.decode(base64.b64decode(obj["data"]))
        return sm
    if kind == "window":
        return Trace(wire_to_nd(obj["time"]), wire_to_nd(obj["ctx"]))
    if kind == "topk":
        return [HotPath(**row) for row in obj["rows"]]
    if kind == "findings":
        return [Finding.from_dict(row) for row in obj["rows"]]
    if kind == "stripe":
        return wire_to_nd(obj["profiles"]), wire_to_nd(obj["values"])
    if kind == "value":
        return float(obj["value"])
    raise ValueError(f"unknown result kind {kind!r}")
