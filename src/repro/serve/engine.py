"""Batched serving engines: generation and postmortem queries.

Two request classes share the coalescing philosophy — group work so the
expensive unit (a jitted forward pass; a decoded database plane) is paid
once per group:

* :class:`ServeEngine` — LLM generation: requests are coalesced into
  fixed-size batch slots (padded prompts with a left-aligned layout and
  per-slot length masks are avoided by grouping same-length prompts); the
  decode loop is one jitted ``decode_step`` per token over the whole batch;
* :class:`QueryServer` — postmortem analysis queries served from one
  shared :class:`repro.query.Database`: a batch is sorted by target plane
  so every plane is decoded once and the LRU (with coalesced concurrent
  misses) serves the rest — "the cache does the batching".
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import monotime, recorder


@dataclass
class Request:
    tokens: np.ndarray          # (S,) prompt
    n_new: int


class ServeEngine:
    def __init__(self, model, params, *, max_len: int, max_batch: int = 8):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode_step)

    # -- core batched generation ------------------------------------------------
    def generate(self, prompts: np.ndarray, n_new: int, *, greedy: bool = True,
                 extras: dict | None = None) -> np.ndarray:
        """prompts (B, S) int32 -> (B, n_new) generated tokens."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(n_new):
            out.append(tok)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok[:, None]})
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack([np.asarray(t) for t in out], axis=1)

    # -- request coalescing -------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[np.ndarray]:
        """Group same-shape requests into batches of up to max_batch."""
        buckets: dict[tuple[int, int], list[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault((len(r.tokens), r.n_new), []).append(i)
        results: list[np.ndarray | None] = [None] * len(requests)
        for (S, n_new), idxs in buckets.items():
            for lo in range(0, len(idxs), self.max_batch):
                group = idxs[lo : lo + self.max_batch]
                prompts = np.stack([requests[i].tokens for i in group])
                gen = self.generate(prompts, n_new)
                for row, i in enumerate(group):
                    results[i] = gen[row]
        return results


# ---------------------------------------------------------------------------
# postmortem query serving
# ---------------------------------------------------------------------------

@dataclass
class QueryRequest:
    """One analysis query against a served database.

    ``op`` selects the shape: ``"profile"`` (all metrics of profile
    ``pid``), ``"stripe"`` (metric across profiles of context ``ctx``),
    ``"value"`` (point lookup), ``"topk"`` (hot paths), ``"threshold"``
    (contexts whose summary stat clears ``params["min_value"]``), ``"window"``
    (trace samples of ``pid`` in ``[t0, t1)``).
    """

    op: str
    pid: int | None = None
    ctx: int | None = None
    metric: object = None
    inclusive: bool = False
    k: int = 10
    t0: float = 0.0
    t1: float = float("inf")
    params: dict = field(default_factory=dict)
    # distributed tracing: minted at the HTTP edge (or accepted from
    # X-Trace-Id), rides the wire into shard workers and through replay
    # so every recorded span of this request's life shares one id
    trace_id: str | None = None


@dataclass(frozen=True)
class QueryError:
    """Structured per-request failure: one bad request in a batch resolves
    to this instead of raising out of the batch and poisoning its peers."""

    op: str
    error: str            # exception class name, e.g. "ValueError"
    message: str

    def as_dict(self) -> dict:
        return {"op": self.op, "error": self.error, "message": self.message}


class QueryServer:
    """Serves :class:`QueryRequest` batches from one shared ``Database``.

    The server holds a single :class:`repro.query.Database`; its LRU cache
    is the batching mechanism: :meth:`serve` orders a batch by the plane
    each request touches, so a burst hitting the same profile plane or
    context stripe decodes it once and the rest are cache hits — and
    concurrent misses on one key are coalesced inside the cache itself, so
    multi-threaded callers get the same property without this sort.
    """

    def __init__(self, db):
        self.db = db

    # -- single-request dispatch -------------------------------------------
    def submit(self, req: QueryRequest, db=None):
        """Serve one request.  ``db`` overrides the server's database for
        this call — the epoch-pinning hook: a follower serving a batch
        passes the batch's pinned snapshot so a concurrent epoch switch
        cannot make one reply straddle two databases."""
        from repro.query import (samples_in_window, threshold_contexts,
                                 topk_hot_paths)
        db = self.db if db is None else db
        if req.op == "profile":
            return db.profile_metrics(req.pid)
        if req.op == "stripe":
            return db.stripe(req.ctx, req.metric, inclusive=req.inclusive)
        if req.op == "value":
            return db.value(req.pid, req.ctx, req.metric,
                            inclusive=req.inclusive)
        if req.op == "topk":
            return topk_hot_paths(db, req.metric, k=req.k,
                                  inclusive=req.inclusive, **req.params)
        if req.op == "threshold":
            params = dict(req.params)
            return threshold_contexts(
                db, req.metric, min_value=float(params.pop("min_value", 0.0)),
                inclusive=req.inclusive, **params)
        if req.op == "window":
            return samples_in_window(db, req.pid, req.t0, req.t1)
        if req.op == "findings":
            return self._findings(req, db)
        raise ValueError(f"unknown query op {req.op!r}")

    @staticmethod
    def _findings(req: QueryRequest, db, within_ctx=None, within_pid=None):
        """The ``findings`` op body: run the scatter-clean analyzers.

        ``params`` carries the analyzer selection and threshold overrides
        (``analyzers``, ``thresholds``, ``limit``); ``metric``/``inclusive``
        pick the metric the imbalance analyzer reads.  The ownership masks
        are supplied by shard workers — a single-process server passes
        None and diagnoses everything.
        """
        from repro.diagnose import compute_findings
        params = dict(req.params)
        analyzers = params.pop("analyzers", None)
        thresholds = params.pop("thresholds", None)
        limit = int(params.pop("limit", 0) or 0)
        if params:
            raise ValueError(f"unknown findings params {sorted(params)}; "
                             f"known: analyzers, thresholds, limit")
        return compute_findings(
            db, analyzers=analyzers, metric=req.metric,
            inclusive=req.inclusive, limit=limit, thresholds=thresholds,
            within_ctx=within_ctx, within_pid=within_pid)

    # -- batched serving ----------------------------------------------------
    @staticmethod
    def _locality_key(req: QueryRequest):
        """The plane a request will pull through the cache."""
        try:
            if req.op == "profile" or req.op == "window":
                return (0, int(req.pid or 0))
            if req.op == "stripe":
                return (1, int(req.ctx or 0))
            if req.op == "value":
                return (1, int(req.ctx or 0))  # point lookups route ctx-major
        except (TypeError, ValueError):
            pass  # malformed ids sort with the plane-less ops; submit reports
        return (2, 0)  # summary-only ops: no plane at all

    def serve_one(self, req: QueryRequest, db=None):
        """:meth:`submit` that never raises: failures (unknown op, bad ids,
        missing stores) come back as a :class:`QueryError` result.
        ``db`` is only forwarded when pinned, so ``submit`` overrides that
        predate the epoch hook keep working.

        This is the one place request *execution* happens — in-process
        scheduler windows and shard workers both come through here — so
        it is where the ``decode`` span is recorded (the store/plane
        work the request paid for, whichever process paid it).
        """
        rec = recorder()
        t0 = monotime() if rec.enabled else 0.0
        try:
            res = (self.submit(req) if db is None
                   else self.submit(req, db=db))
        except Exception as e:                          # noqa: BLE001
            res = QueryError(op=str(getattr(req, "op", "?")),
                             error=type(e).__name__, message=str(e))
        if rec.enabled:
            rec.record("decode", str(getattr(req, "op", "?")), t0,
                       monotime() - t0,
                       trace_id=getattr(req, "trace_id", None) or "")
        return res

    def serve(self, requests: list[QueryRequest], db=None) -> list:
        """Serve a batch in plane-locality order.

        Failures are isolated per request: one malformed request yields a
        :class:`QueryError` in its slot and the rest of the batch is served
        normally (a poisoned request must not kill its batch peers).
        ``db`` pins the whole batch to one database handle (epoch
        consistency for followers).
        """
        order = sorted(range(len(requests)),
                       key=lambda i: self._locality_key(requests[i]))
        results: list = [None] * len(requests)
        for i in order:
            results[i] = self.serve_one(requests[i], db=db)
        return results
