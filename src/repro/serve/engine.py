"""Batched serving engine: request coalescing + prefill/decode loop.

Requests are coalesced into fixed-size batch slots (padded prompts with a
left-aligned layout and per-slot length masks are avoided by grouping
same-length prompts; mixed lengths are right-padded and masked via the
position argument).  The decode loop is one jitted ``decode_step`` per
token over the whole batch — the ``decode_*`` dry-run shapes lower exactly
this function.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    tokens: np.ndarray          # (S,) prompt
    n_new: int


class ServeEngine:
    def __init__(self, model, params, *, max_len: int, max_batch: int = 8):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode_step)

    # -- core batched generation ------------------------------------------------
    def generate(self, prompts: np.ndarray, n_new: int, *, greedy: bool = True,
                 extras: dict | None = None) -> np.ndarray:
        """prompts (B, S) int32 -> (B, n_new) generated tokens."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for _ in range(n_new):
            out.append(tok)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": tok[:, None]})
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.stack([np.asarray(t) for t in out], axis=1)

    # -- request coalescing -------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[np.ndarray]:
        """Group same-shape requests into batches of up to max_batch."""
        buckets: dict[tuple[int, int], list[int]] = {}
        for i, r in enumerate(requests):
            buckets.setdefault((len(r.tokens), r.n_new), []).append(i)
        results: list[np.ndarray | None] = [None] * len(requests)
        for (S, n_new), idxs in buckets.items():
            for lo in range(0, len(idxs), self.max_batch):
                group = idxs[lo : lo + self.max_batch]
                prompts = np.stack([requests[i].tokens for i in group])
                gen = self.generate(prompts, n_new)
                for row, i in enumerate(group):
                    results[i] = gen[row]
        return results
