"""Chaos schedule harness: timed fault injection against a live server.

A :class:`ChaosSchedule` is a deterministic list of :class:`ChaosEvent`s
applied to a running :class:`~repro.serve.shard.ShardedQueryServer`
while load is in flight — the proof harness behind the replicated
serving design: with R-way ownership, any single replica's death (or a
whole shard group's), transport message loss, added latency, or a hung
peer must cost *latency only*, never a failed client request and never
a byte of divergence from the unfaulted run.

Event kinds:

* ``kill``       — SIGKILL one shard's worker process (the classic
  worker-death drill; recovery = failover to a live replica + respawn).
* ``kill_group`` — SIGKILL several workers at the same instant (a whole
  shard group / host dying; ``shards`` lists the group).
* ``drop``       — the parent->worker transport silently discards
  requests for ``duration_s`` (message loss; recovery = stall
  detection -> suspect -> hung-kill -> replay).
* ``delay``      — every transport send sleeps ``delay_s`` for
  ``duration_s`` (a slow link).
* ``stall``      — worker replies stop being delivered for
  ``duration_s`` even though the worker is alive (a hung peer /
  partition that heals).

Used by ``tests/test_chaos.py`` (the ``-m chaos`` suite) and
``benchmarks/serve_load.py --chaos``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from repro.obs import monotime

#: event kinds understood by ChaosSchedule.run
KINDS = ("kill", "kill_group", "drop", "delay", "stall")


@dataclass(frozen=True)
class ChaosEvent:
    """One timed fault: fires ``at_s`` seconds after schedule start."""

    at_s: float
    kind: str            # one of KINDS
    shard: int = 0       # target shard (ignored by kill_group)
    shards: tuple = ()   # kill_group targets
    duration_s: float = 0.5   # fault window for drop/delay/stall
    delay_s: float = 0.02     # per-send sleep for delay

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}")


@dataclass
class AppliedEvent:
    """Journal entry: what actually fired, when, at what."""

    t_s: float
    kind: str
    targets: tuple
    detail: dict = field(default_factory=dict)


class ChaosSchedule:
    """Apply a fixed event list to a server on a background thread.

    The schedule is deterministic by construction (no randomness — vary
    the event list, not a seed), so a faulted run can be compared
    byte-for-byte against an unfaulted run of the same request stream.
    """

    def __init__(self, server, events: list[ChaosEvent]):
        self.server = server
        self.events = sorted(events, key=lambda e: e.at_s)
        self.applied: list[AppliedEvent] = []
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._t0 = 0.0

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ChaosSchedule":
        self._t0 = monotime()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="chaos-schedule")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def __enter__(self) -> "ChaosSchedule":
        return self.start()

    def __exit__(self, *a) -> None:
        self.stop()
        self.join(timeout=5.0)

    # -- engine -------------------------------------------------------------
    def _run(self) -> None:
        for ev in self.events:
            wait = self._t0 + ev.at_s - monotime()
            if wait > 0 and self._stop.wait(wait):
                return
            if self._stop.is_set():
                return
            self._apply(ev)

    def _apply(self, ev: ChaosEvent) -> None:
        t = monotime() - self._t0
        srv = self.server
        if ev.kind == "kill":
            pid = srv.kill_worker(ev.shard)
            self.applied.append(AppliedEvent(t, "kill", (ev.shard,),
                                             {"pid": pid}))
        elif ev.kind == "kill_group":
            targets = tuple(ev.shards) or (ev.shard,)
            pids = [srv.kill_worker(s) for s in targets]
            self.applied.append(AppliedEvent(t, "kill_group", targets,
                                             {"pids": pids}))
        else:
            srv.inject_fault(ev.shard, ev.kind, ev.duration_s,
                             delay_s=ev.delay_s)
            self.applied.append(AppliedEvent(
                t, ev.kind, (ev.shard,),
                {"duration_s": ev.duration_s}))

    def report(self) -> list[dict]:
        return [{"t_s": round(a.t_s, 3), "kind": a.kind,
                 "targets": list(a.targets), **a.detail}
                for a in self.applied]


def default_schedule(n_shards: int, *, span_s: float = 2.0,
                     kinds: tuple = ("kill", "drop", "stall")
                     ) -> list[ChaosEvent]:
    """A canned schedule spreading one event of each requested kind
    across ``span_s`` seconds, rotating over shards — the smoke-level
    dose used by ``serve_load --chaos``."""
    kinds = tuple(k for k in kinds if k in KINDS) or ("kill",)
    step = span_s / (len(kinds) + 1)
    return [ChaosEvent(at_s=step * (i + 1), kind=k, shard=i % n_shards,
                       duration_s=min(0.5, step))
            for i, k in enumerate(kinds)]
