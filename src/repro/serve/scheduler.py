"""Cross-request micro-batch scheduler with admission control.

The query engine's economics reward batching: a window of concurrent
requests sorted by :meth:`QueryServer._locality_key` decodes every hot
plane once (the rest are LRU hits), and one worker wake-up is amortized
over the whole window instead of paid per request.  This module supplies
the missing piece between "a Database that can batch" and "a service under
open-loop load":

* **admission control** — a bounded queue; when it is full, :meth:`submit`
  raises :class:`Overloaded` *immediately* (the HTTP layer maps it to
  ``429 Retry-After``), so overload degrades to fast rejections instead of
  unbounded queueing and collapse;
* **micro-batch windows** — workers collect up to ``max_batch`` requests,
  waiting at most ``max_wait_ms`` after the first arrival, then serve the
  window in plane-locality order through :meth:`QueryServer.serve_one`;
* **adaptive windows** — with ``adaptive_wait`` (the default), a worker
  holds a window open for ``max_wait_ms`` only while every *other* worker
  is busy serving: if a peer is idle-parked, new arrivals would be picked
  up immediately anyway, so waiting buys no batching — the window flushes
  at once and low-load p50 stays at service time, not service + window;
* **deadlines** — every request carries one; a request that expires while
  queued resolves to a ``QueryError("DeadlineExceeded")`` without touching
  the stores (shedding stale work is the other half of backpressure);
* **runtime executor** — the window-serving loops run on a
  :mod:`repro.runtime` executor (``threads`` by default, ``serial`` for
  deterministic debugging), the same substrate the aggregator uses.

**Sharded backends** (:class:`~repro.serve.shard.ShardedQueryServer`)
swap the execution model: parent-side windows would only re-serialize
what the worker processes already parallelize, so :meth:`submit_many`
dispatches straight from the submitting thread through the server's
``serve_window_async`` (which dedupes the call and sends one batch
message per shard) and chains the returned futures.  Admission control
becomes a *per-shard* bound on dispatched-but-unanswered requests — one
hot shard rejects while the others keep admitting — and batching across
calls falls out of each worker's own plane cache.

Results are delivered through ``concurrent.futures.Future``s; per-request
failures resolve (not raise) as :class:`~repro.serve.engine.QueryError`,
so one poisoned request never disturbs its window peers.
"""
from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass

from repro.obs import HIST_EDGES_US, Histogram, MetricsRegistry, monotime, recorder
from repro.serve.engine import QueryError, QueryRequest, QueryServer

# Both names predate repro.obs and are re-exported for compatibility:
# the histogram now lives in the registry (`serve/http.py` and
# `ingest/server.py` used to re-import this module's private copy).
_HIST_EDGES_US = HIST_EDGES_US
LatencyHistogram = Histogram


class Overloaded(RuntimeError):
    """Admission queue full; retry after ``retry_after_s`` seconds."""

    def __init__(self, retry_after_s: float):
        super().__init__(f"admission queue full; retry after "
                         f"{retry_after_s:.2f}s")
        self.retry_after_s = float(retry_after_s)


@dataclass
class _Pending:
    req: QueryRequest
    future: Future
    enq_t: float
    deadline: float
    # epoch pin: a retained EpochSwitcher handle; the worker serves this
    # request against pin.db and releases the pin at every terminal path,
    # so an epoch switch mid-queue cannot split one call across snapshots
    pin: object | None = None


class BatchScheduler:
    """Admission-controlled micro-batching front of one :class:`QueryServer`.

    ``max_batch=1`` degrades to one-request-at-a-time serving (the
    benchmark baseline).  ``max_wait_ms`` bounds how long a worker holds a
    window open after its first request; ``0`` (the default) is
    *opportunistic* batching — serve everything already queued, never
    stall an idle worker.  A small positive wait trades first-request
    latency for fuller windows (better plane dedup) when traffic is
    sparse but bursty; ``adaptive_wait`` (default) skips the wait
    whenever an idle peer worker would make it pure latency.

    With a sharded server, ``max_queue`` bounds each shard's
    dispatched-but-unanswered depth instead of a parent queue, and the
    executor/window knobs are inert (dispatch happens on the submitting
    thread; the worker processes do the batching).
    """

    def __init__(self, server: QueryServer, *, max_batch: int = 16,
                 max_wait_ms: float = 0.0, max_queue: int = 256,
                 executor: str = "threads", n_workers: int = 4,
                 default_timeout_s: float = 30.0,
                 adaptive_wait: bool = True, tenant: str = ""):
        self.server = server
        # multi-tenant fronts run one scheduler per tenant: ``max_queue``
        # is then that tenant's admission budget, and the name rides the
        # metrics so rejections are attributable
        self.tenant = str(tenant)
        self.max_batch = max(1, int(max_batch))
        self.max_wait_s = max(0.0, float(max_wait_ms)) / 1e3
        self.max_queue = max(1, int(max_queue))
        self.default_timeout_s = float(default_timeout_s)
        self.adaptive_wait = bool(adaptive_wait)
        self._executor_name = executor
        self.n_workers = 1 if executor == "serial" else max(1, int(n_workers))

        # sharded-backend hooks (absent on in-process QueryServers)
        self.n_shards = max(1, int(getattr(server, "n_shards", 1)))
        self._shard_of = getattr(server, "shard_of", None)
        self._serve_window_async = getattr(server, "serve_window_async",
                                           None)
        self._direct = (self._serve_window_async is not None
                        and self._shard_of is not None)

        # direct-mode admission ledger: requests admitted per shard and
        # not yet completed (exact under self._lock — the server-side
        # inflight gauge lags dispatch, so bounding on it alone would let
        # concurrent submitters overshoot the bound)
        self._admitted = [0] * self.n_shards
        self._q: deque[_Pending] = deque()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._stopped = True
        self._idle = 0     # workers parked waiting for any work
        self._holding = 0  # workers holding a window open on max_wait
        self._runner: threading.Thread | None = None
        self._ewma_service_s = 1e-3  # per-request service time estimate

        # observability: registry-backed instruments with the historical
        # shapes (counters guarded by self._lock exactly as before; the
        # group's own lock only matters for out-of-band readers)
        self.obs = MetricsRegistry()
        self.counters = self.obs.group(
            "scheduler", {"submitted": 0, "completed": 0, "rejected": 0,
                          "expired": 0, "errors": 0, "batches": 0,
                          "batched_requests": 0})
        # op -> Histogram (service time)
        self.latency = self.obs.histogram_family("scheduler.latency", "op")
        self.queue_wait = self.obs.histogram("scheduler.queue_wait")
        self.obs.gauge("scheduler.queue_depth", self.depth)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "BatchScheduler":
        """Spin up the window-serving loops on the runtime executor."""
        if self._direct:
            # sharded backend: no parent-side serving loops to start —
            # dispatch happens inline on submitting threads
            with self._lock:
                self._stopped = False
            return self
        from repro.runtime import get_executor
        # resolve the executor BEFORE flipping state: a bad executor name
        # must not leave a "running" scheduler with zero workers
        ex = get_executor(self._executor_name, self.n_workers)
        with self._lock:
            if not self._stopped:
                ex.close()
                return self
            self._stopped = False

        def run():
            try:
                with ex:
                    ex.parallel_for(self.n_workers, self._worker_loop)
            except BaseException as e:  # worker crash: fail queued futures
                self._fail_all(e)

        self._runner = threading.Thread(target=run, daemon=True,
                                        name="serve-scheduler")
        self._runner.start()
        return self

    def stop(self) -> None:
        with self._cond:
            if self._stopped:
                return
            self._stopped = True
            self._cond.notify_all()
        if self._runner is not None:
            self._runner.join(timeout=10.0)
        self._fail_all(RuntimeError("scheduler stopped"))

    @staticmethod
    def _resolve(fut: Future, result=None, exc: BaseException | None = None
                 ) -> None:
        """set_result/set_exception that tolerates a caller-side cancel
        racing in after our done-check — a lost cancel race must never
        take down the worker loop."""
        try:
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(result)
        except InvalidStateError:
            pass

    def _fail_all(self, exc: BaseException) -> None:
        with self._cond:
            pending, self._q = list(self._q), deque()
        for p in pending:
            if not p.future.done():
                self._resolve(p.future, exc=exc)
            if p.pin is not None:
                p.pin.release()

    def __enter__(self) -> "BatchScheduler":
        return self.start()

    def __exit__(self, *a) -> None:
        self.stop()

    # -- submission (admission control) --------------------------------------
    def depth(self) -> int:
        with self._lock:
            return len(self._q) + self._inflight_depth()

    def _inflight_depth(self) -> int:
        return sum(self._admitted) if self._direct else 0

    def _retry_after_locked(self) -> float:
        if self._direct:
            # a hot shard's backlog drains through its one worker
            # process; the parent thread count is irrelevant to it
            est = max(self._admitted) * self._ewma_service_s
        else:
            est = len(self._q) * self._ewma_service_s / self.n_workers
        return max(0.05, min(est, 30.0))

    def retry_after_s(self) -> float:
        """Rough time until the queue drains enough to admit again."""
        with self._lock:
            return self._retry_after_locked()

    def submit(self, req: QueryRequest, *, timeout_s: float | None = None,
               pin=None) -> Future:
        return self.submit_many([req], timeout_s=timeout_s, pin=pin)[0]

    def submit_many(self, reqs: list[QueryRequest], *,
                    timeout_s: float | None = None, pin=None) -> list[Future]:
        """Admit a group atomically: all enqueued, or :class:`Overloaded`.

        Atomic admission keeps multi-request HTTP calls coherent — a call
        either gets every answer or a single 429, never a half-served body.

        ``pin`` (an epoch handle with ``retain``/``release``/``db``) pins
        every admitted request to one database snapshot: retained once per
        request here, served against ``pin.db``, and released at every
        terminal path (served, expired, cancelled, failed) — in-process
        backends only; a sharded backend gets call-level epoch consistency
        from its own single-dispatch reopen lock.
        """
        timeout_s = self.default_timeout_s if timeout_s is None else timeout_s
        now = monotime()
        if self._direct:
            if pin is not None:
                raise ValueError(
                    "epoch pins apply to in-process serving; a sharded "
                    "backend pins whole dispatches via reopen()")
            return self._submit_direct(list(reqs), now, timeout_s)
        with self._cond:
            if self._stopped:
                raise RuntimeError("scheduler is not running")
            if len(self._q) + len(reqs) > self.max_queue:
                self.counters["rejected"] += len(reqs)
                raise Overloaded(self._retry_after_locked())
            out = []
            for req in reqs:
                p = _Pending(req, Future(), now, now + timeout_s,
                             pin.retain() if pin is not None else None)
                self._q.append(p)
                out.append(p.future)
            self.counters["submitted"] += len(reqs)
            # wake enough workers to spread a multi-request call; one
            # notify would serve it as sequential windows on one worker
            self._cond.notify(min(len(reqs), self.n_workers))
        return out

    # -- sharded direct dispatch ---------------------------------------------
    def _submit_direct(self, reqs: list[QueryRequest], now: float,
                       timeout_s: float) -> list[Future]:
        """Admission + inline async dispatch for a sharded backend.

        The bound is per shard, on dispatched-but-unanswered depth: a call
        is rejected only when a shard it targets is saturated, so a hot
        shard cannot starve admission for traffic bound elsewhere.
        Scatter requests count against every shard (they run on all).
        """
        targets = []
        incoming: dict[int, int] = {}
        for req in reqs:
            s = self._shard_of(req)
            shards = tuple(range(self.n_shards)) if s is None else (int(s),)
            targets.append(shards)
            for t in shards:
                incoming[t] = incoming.get(t, 0) + 1
        with self._lock:
            if self._stopped:
                raise RuntimeError("scheduler is not running")
            if any(self._admitted[s] + k > self.max_queue
                   for s, k in incoming.items()):
                self.counters["rejected"] += len(reqs)
                raise Overloaded(self._retry_after_locked())
            for s, k in incoming.items():
                self._admitted[s] += k  # released in _chain_cb
            self.counters["submitted"] += len(reqs)
            self.counters["batches"] += 1
            self.counters["batched_requests"] += len(reqs)
        try:
            server_futs = self._serve_window_async(reqs)
        except BaseException:
            with self._lock:  # dispatch failed: release the admission
                for s, k in incoming.items():
                    self._admitted[s] -= k
            raise
        out = []
        n = max(len(reqs), 1)
        for req, sf, shards in zip(reqs, server_futs, targets):
            p = _Pending(req, Future(), now, now + timeout_s)
            sf.add_done_callback(self._chain_cb(p, now, n, shards))
            out.append(p.future)
        return out

    def _chain_cb(self, p: _Pending, t0: float, window_n: int,
                  shards: tuple[int, ...] = ()):
        """Completion hook for one directly-dispatched request: forward
        the shard result to the caller's future (on the shard pump
        thread) and do the per-request bookkeeping."""

        def done(f) -> None:
            exc = f.exception()
            res = (QueryError(op=str(getattr(p.req, "op", "?")),
                              error=type(exc).__name__, message=str(exc))
                   if exc is not None else f.result())
            if not p.future.cancelled():
                self._resolve(p.future, res)
            dt = monotime() - t0
            op = str(getattr(p.req, "op", "?"))
            rec = recorder()
            if rec.enabled:
                # one span per admitted slot: coalesced duplicates each
                # keep their own _Pending (and their own trace id), so
                # every caller's trace shows its dispatch
                rec.record("dispatch", op, t0, dt,
                           trace_id=getattr(p.req, "trace_id", None) or "")
                if isinstance(res, QueryError):
                    rec.dump(f"query_error op={op} error={res.error}")
            with self._lock:
                for s in shards:
                    self._admitted[s] -= 1
                self.counters["completed"] += 1
                if isinstance(res, QueryError):
                    self.counters["errors"] += 1
                self.latency.labels(op).observe(dt)
                self.queue_wait.observe(max(t0 - p.enq_t, 0.0))
                # call completion time / call size approximates the
                # per-request service time for the drain estimate
                self._ewma_service_s += 0.05 * (dt / window_n
                                                - self._ewma_service_s)

        return done

    # -- window serving -------------------------------------------------------
    def _collect(self) -> list[_Pending] | None:
        """Block for the next micro-batch window; ``None`` on shutdown.

        The wait loop honors ``adaptive_wait``: holding a window open only
        pays when every other worker is busy serving — an idle peer would
        absorb new arrivals instantly, so the window flushes immediately.
        """
        with self._cond:
            while not self._q:
                if self._stopped:
                    return None
                self._idle += 1
                if self._holding:
                    # a newly idle peer invalidates any held-open window
                    # (adaptive rule) — wake the holders to re-check;
                    # gated on _holding so parked idle workers don't
                    # wake each other in an endless ping-pong
                    self._cond.notify_all()
                try:
                    self._cond.wait()
                finally:
                    self._idle -= 1
            batch = [self._q.popleft()]
            window_end = monotime() + self.max_wait_s
            while len(batch) < self.max_batch:
                if self._q:
                    batch.append(self._q.popleft())
                    continue
                remaining = window_end - monotime()
                if remaining <= 0 or self._stopped:
                    break
                if self.adaptive_wait and self._idle > 0:
                    break  # an idle peer makes waiting pure latency
                self._holding += 1
                try:
                    self._cond.wait(remaining)
                finally:
                    self._holding -= 1
            return batch

    def _execute(self, batch: list[_Pending]) -> None:
        try:
            self._execute_inner(batch)
        finally:
            # every pending passes through here exactly once (served,
            # expired, or cancelled) — the single release point that
            # balances submit_many's per-request retain
            for p in batch:
                if p.pin is not None:
                    p.pin.release()

    def _execute_inner(self, batch: list[_Pending]) -> None:
        now = monotime()
        live: list[_Pending] = []
        for p in batch:
            if p.future.cancelled():
                continue
            if now > p.deadline:
                with self._lock:
                    self.counters["expired"] += 1
                self._resolve(p.future, QueryError(
                    op=str(getattr(p.req, "op", "?")),
                    error="DeadlineExceeded",
                    message=f"spent {now - p.enq_t:.3f}s queued"))
                continue
            live.append(p)
        if not live:
            return
        with self._lock:
            self.counters["batches"] += 1
            self.counters["batched_requests"] += len(live)
        # plane-locality order: every hot plane decodes once per window
        order = sorted(range(len(live)),
                       key=lambda i: self.server._locality_key(live[i].req))
        rec = recorder()
        observed: list[tuple[str, float, float, float, bool, str]] = []
        for i in order:
            p = live[i]
            t0 = monotime()
            res = (self.server.serve_one(p.req, db=p.pin.db)
                   if p.pin is not None else self.server.serve_one(p.req))
            dt = monotime() - t0
            observed.append((str(getattr(p.req, "op", "?")), dt,
                             t0 - p.enq_t, t0, isinstance(res, QueryError),
                             getattr(p.req, "trace_id", None) or ""))
            if not p.future.cancelled():
                self._resolve(p.future, res)
        if rec.enabled:
            for op, dt, waited, t0, failed, tid in observed:
                rec.record("queue_wait", op, t0 - max(waited, 0.0),
                           max(waited, 0.0), trace_id=tid)
                rec.record("dispatch", op, t0, dt, trace_id=tid)
                if failed:
                    rec.dump(f"query_error op={op}")
        # one bookkeeping pass per window, not per request — the lock is
        # shared with submit(), so per-request acquisition would tax the
        # serving loop exactly where batching should be amortizing it
        with self._lock:
            for op, dt, waited, _t0, failed, _tid in observed:
                self.counters["completed"] += 1
                if failed:
                    self.counters["errors"] += 1
                self.latency.labels(op).observe(dt)
                self.queue_wait.observe(waited)
                self._ewma_service_s += 0.05 * (dt - self._ewma_service_s)

    def _worker_loop(self, w: int) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            self._execute(batch)

    # -- observability --------------------------------------------------------
    def metrics(self) -> dict:
        with self._lock:
            out = dict(self.counters)
            out["queue_depth"] = len(self._q) + self._inflight_depth()
            if self.tenant:
                out["tenant"] = self.tenant
            out["n_shards"] = self.n_shards
            out["direct_dispatch"] = self._direct
            if self._direct:
                out["admitted_per_shard"] = list(self._admitted)
            out["max_queue"] = self.max_queue
            out["max_batch"] = self.max_batch
            out["max_wait_ms"] = self.max_wait_s * 1e3
            out["adaptive_wait"] = self.adaptive_wait
            out["workers"] = self.n_workers
            out["executor"] = self._executor_name
            out["ewma_service_ms"] = self._ewma_service_s * 1e3
            out["mean_batch_size"] = (
                self.counters["batched_requests"]
                / max(self.counters["batches"], 1))
            out["latency"] = {op: h.as_dict() for op, h in self.latency.items()}
            out["queue_wait"] = self.queue_wait.as_dict()
        return out
