"""Serving subsystem: batched generation + the query service.

* :class:`ServeEngine` / :class:`QueryServer` — in-process batch engines
  (:mod:`repro.serve.engine`);
* :class:`BatchScheduler` — cross-request micro-batch windows with
  per-shard admission control, adaptive wait, and deadlines
  (:mod:`repro.serve.scheduler`);
* :class:`ShardedQueryServer` — multi-process sharded serving with
  consistent-hash plane routing, shm payload transport, and a
  respawn-and-replay supervisor (:mod:`repro.serve.shard`);
* :class:`QueryHTTPServer` / :class:`QueryClient` — the stdlib HTTP
  transport and its typed client (:mod:`repro.serve.http` / ``client``),
  with :class:`RetryPolicy` for client-side backoff;
* :class:`TenantBackend` — one named tenant's engine/scheduler/follower
  stack behind a shared multi-tenant front (:mod:`repro.serve.tenant`);
* :func:`warm_cache` — stats-driven startup plane preloading
  (:mod:`repro.serve.warm`).
"""
from repro.serve.client import (JSONClient, QueryClient, RequestFailed,
                                RetryBudgetExceeded, RetryPolicy,
                                ServerOverloaded, TransportError)
from repro.serve.engine import (QueryError, QueryRequest, QueryServer,
                                Request, ServeEngine)
from repro.serve.http import QueryHTTPServer
from repro.serve.scheduler import BatchScheduler, Overloaded
from repro.serve.shard import ConsistentHashRing, ShardedQueryServer
from repro.serve.tenant import TenantBackend, parse_tenant_arg
from repro.serve.warm import plan_warm, warm_cache

__all__ = [
    "ServeEngine", "Request",
    "QueryServer", "QueryRequest", "QueryError",
    "BatchScheduler", "Overloaded",
    "ShardedQueryServer", "ConsistentHashRing",
    "QueryHTTPServer", "QueryClient", "JSONClient", "ServerOverloaded",
    "RequestFailed", "TransportError", "RetryPolicy", "RetryBudgetExceeded",
    "TenantBackend", "parse_tenant_arg",
    "plan_warm", "warm_cache",
]
