"""Serving subsystem: batched generation + the query service.

* :class:`ServeEngine` / :class:`QueryServer` — in-process batch engines
  (:mod:`repro.serve.engine`);
* :class:`BatchScheduler` — cross-request micro-batch windows with
  admission control and deadlines (:mod:`repro.serve.scheduler`);
* :class:`QueryHTTPServer` / :class:`QueryClient` — the stdlib HTTP
  transport and its typed client (:mod:`repro.serve.http` / ``client``);
* :func:`warm_cache` — stats-driven startup plane preloading
  (:mod:`repro.serve.warm`).
"""
from repro.serve.client import QueryClient, RequestFailed, ServerOverloaded
from repro.serve.engine import (QueryError, QueryRequest, QueryServer,
                                Request, ServeEngine)
from repro.serve.http import QueryHTTPServer
from repro.serve.scheduler import BatchScheduler, Overloaded
from repro.serve.warm import plan_warm, warm_cache

__all__ = [
    "ServeEngine", "Request",
    "QueryServer", "QueryRequest", "QueryError",
    "BatchScheduler", "Overloaded",
    "QueryHTTPServer", "QueryClient", "ServerOverloaded", "RequestFailed",
    "plan_warm", "warm_cache",
]
