"""Per-tenant serving backends for the multi-tenant HTTP front.

One :class:`TenantBackend` is the execution stack the single-tenant
``QueryHTTPServer`` always assembled — engine (in-process or sharded),
batch scheduler, optional epoch switcher, warm plan — minus the HTTP
transport.  A multi-tenant front holds one backend per named database
behind one listener, so:

* **admission is isolated**: each tenant gets its own
  :class:`~repro.serve.scheduler.BatchScheduler` with its own queue
  budget — one tenant saturating its budget is 429'd while its
  neighbors' queues stay empty;
* **epoch following is per tenant**: each backend polls its own
  snapshot root, so teams publish on independent cadences;
* **metrics stay attributable**: every backend's registries render with
  a ``tenant="name"`` label in the merged Prometheus exposition.
"""
from __future__ import annotations

import re

from repro.obs import MetricsRegistry, monotime
from repro.query.database import Database
from repro.query.epoch import EpochSwitcher, wait_for_epoch
from repro.serve.engine import QueryServer
from repro.serve.scheduler import BatchScheduler
from repro.serve.shard import ShardedQueryServer
from repro.serve.warm import warm_cache

_TENANT_NAME_RE = re.compile(r"^[A-Za-z0-9._\-]{1,64}$")


def valid_tenant_name(name) -> bool:
    return isinstance(name, str) and bool(_TENANT_NAME_RE.match(name))


def parse_tenant_arg(spec: str) -> tuple[str, str, int | None]:
    """Parse one ``--tenant`` CLI value: ``name=path[,queue=N]``.

    Returns ``(name, path, max_queue_or_None)``.
    """
    head, _, tail = spec.partition(",")
    name, sep, path = head.partition("=")
    if not sep or not path:
        raise ValueError(f"--tenant needs name=path, got {spec!r}")
    if not valid_tenant_name(name):
        raise ValueError(f"invalid tenant name {name!r} "
                         f"(alnum, dot, dash, underscore; max 64)")
    queue = None
    if tail:
        k, _, v = tail.partition("=")
        if k.strip() != "queue":
            raise ValueError(f"unknown --tenant option {k!r}; known: queue")
        queue = int(v)
    return name, path, queue


class TenantBackend:
    """One tenant's execution stack behind a shared HTTP front."""

    def __init__(self, name: str, db, *, follow: bool = False,
                 follow_wait_s: float = 60.0,
                 follow_cache_bytes: int = 64 << 20,
                 batching: bool = True, max_batch: int = 16,
                 max_wait_ms: float = 0.0, max_queue: int = 256,
                 executor: str = "threads", n_workers: int = 4,
                 default_timeout_s: float = 30.0,
                 adaptive_wait: bool = True, warm_bytes: int | None = 0,
                 shards: int = 0, shard_cache_bytes: int | None = None,
                 shard_slab_bytes: int = 4 << 20, shard_slabs: int = 8,
                 replicas: int = 2, shard_transport: str = "shm",
                 hedge_ms: float | None = None):
        if not valid_tenant_name(name):
            raise ValueError(f"invalid tenant name {name!r}")
        self.name = name
        self.switcher: EpochSwitcher | None = None
        if follow:
            # ``db`` is the tenant's snapshot ROOT; open whatever CURRENT
            # points at and track it
            root = str(db)
            wait_for_epoch(root, timeout_s=follow_wait_s)
            self.switcher = EpochSwitcher(root,
                                          cache_bytes=follow_cache_bytes)
            self._db = None
        elif isinstance(db, (str, bytes)) or hasattr(db, "__fspath__"):
            raise TypeError(f"tenant {name!r}: pass an open Database (or "
                            f"follow=True with a snapshot root)")
        else:
            self._db = db
        db = self.db
        self.shards = max(0, int(shards))
        self.sharded: ShardedQueryServer | None = None
        if self.shards:
            self.sharded = ShardedQueryServer(
                db.db_dir, self.shards,
                cache_bytes=shard_cache_bytes or db.cache.capacity_bytes,
                warm_bytes=warm_bytes, n_slabs=shard_slabs,
                slab_bytes=shard_slab_bytes, replicas=replicas,
                transport=shard_transport, hedge_ms=hedge_ms)
            self.engine = self.sharded
        else:
            self.engine = QueryServer(db)
        self.batching = bool(batching)
        self.scheduler = BatchScheduler(
            self.engine, max_batch=max_batch, max_wait_ms=max_wait_ms,
            max_queue=max_queue, executor=executor, n_workers=n_workers,
            default_timeout_s=default_timeout_s,
            adaptive_wait=adaptive_wait,
            tenant=name) if self.batching else None
        self._warm_bytes = warm_bytes
        self.warm_report: dict | None = None
        self.follow_errors = 0
        self.obs = MetricsRegistry()
        self.reopen_hist = self.obs.histogram("http.epoch_reopen")

    @property
    def db(self) -> Database:
        """The database answering *new* requests right now."""
        if self.switcher is not None:
            return self.switcher.db
        return self._db

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> None:
        if self.sharded is not None:
            self.sharded.start()
            self.warm_report = {"sharded": self.sharded.warm_reports()}
        elif self._warm_bytes is None or self._warm_bytes > 0:
            self.warm_report = warm_cache(self.db, self._warm_bytes or None)
        if self.scheduler is not None:
            self.scheduler.start()

    def stop(self) -> None:
        if self.scheduler is not None:
            self.scheduler.stop()
        if self.sharded is not None:
            self.sharded.close()
        if self.switcher is not None:
            self.switcher.close()

    # -- epoch following ------------------------------------------------------
    def poll_follow(self) -> None:
        """One follow tick: swing to a newly published epoch if any.
        Called from the front's single follower thread for every tenant."""
        if self.switcher is None:
            return
        try:
            if not self.switcher.poll():
                return
            t0 = monotime()
            if self.sharded is not None:
                # all workers swing together; the window lock inside
                # reopen() keeps every dispatch single-epoch
                self.sharded.reopen(self.switcher.db.db_dir)
            else:
                # in-process: future batches default to the new epoch;
                # in-flight ones hold pins on the old handle
                self.engine.db = self.switcher.db
            self.reopen_hist.observe(monotime() - t0)
        except Exception:                                   # noqa: BLE001
            # a torn transition (e.g. SnapshotGone racing GC) is retried
            # on the next poll; keep serving the old epoch
            self.follow_errors += 1

    # -- reporting ------------------------------------------------------------
    def health_fragment(self) -> dict:
        out = {"profiles": self.db.n_profiles,
               "contexts": self.db.n_contexts,
               "shards": self.shards, "batching": self.batching}
        if self.switcher is not None:
            out["epoch"] = self.switcher.epoch
        return out

    def metrics_fragment(self) -> dict:
        out = {"cache": self.db.cache_stats(),
               "db_counters": dict(self.db.counters),
               "warm": self.warm_report,
               "scheduler": (self.scheduler.metrics()
                             if self.scheduler is not None else None),
               "shards": (self.sharded.metrics()
                          if self.sharded is not None else None)}
        if self.switcher is not None:
            out["epoch"] = {"current": self.switcher.epoch,
                            "transitions": self.switcher.transitions,
                            "follow_errors": self.follow_errors,
                            "reopen": self.reopen_hist.as_dict()}
        return out

    def registries(self) -> list:
        """Every registry this tenant contributes to the merged scrape."""
        return [self.obs, getattr(self.db, "obs", None),
                self.scheduler.obs if self.scheduler is not None else None,
                self.sharded.obs if self.sharded is not None else None]
