"""Peer transport for the sharded query service: shm queues or framed TCP.

The parent <-> shard-worker link was born as a pair of ``mp.Queue``s plus
a shared-memory slab arena — perfect for same-host workers, useless the
moment a shard group lives in another process tree or on another host
(ROADMAP item 2: the distributed-memory half of the paper's design).
This module puts both links behind one tiny interface so the supervisor,
the chaos harness, and the worker loop never care which one they hold:

* :class:`QueuePeer` — the original path: pickled messages over
  ``mp.Queue``, plane payloads over the shm slab arena
  (``supports_slabs``).
* :class:`TcpPeer` — length-prefixed frames over a TCP socket.  A frame
  is ``8-byte little-endian length + pickled message``; the first frame
  each way is a JSON **hello** (never pickle before the peer is
  authenticated) carrying a per-spawn token and the transports the
  worker can offer, so the transport is *negotiated per peer*: the
  listener answers with the one it picked.  Connect and read honor
  per-peer timeouts; a worker whose connection drops reconnects with
  bounded exponential backoff and re-handshakes, and gives up (exits,
  so the supervisor respawns it) after ``reconnect_attempts``.

Failure signalling is uniform: ``recv`` raises :class:`PeerTimeout`
when nothing arrived in time and :class:`PeerClosed` when the link is
gone — the supervisor turns the former into health *misses* and the
latter into the death/respawn path.

:class:`PeerHealth` is the per-owner health state machine the router
consults (``alive -> suspect -> dead -> rejoining``): misses accumulate
from read timeouts / missed replies, any successful reply resets to
alive, death is terminal until the replacement worker reports ready.

:class:`ChaosState` is the fault-injection seam used by tests and
``benchmarks/serve_load.py --chaos``: a peer consults it on every
send/recv, so message **drops**, added **delays**, and **stalls** (a
hung peer that stops delivering without dying) are injected exactly at
the transport boundary they would occur at in production.
"""
from __future__ import annotations

import hmac
import json
import pickle
import queue as queue_mod
import socket
import struct
import threading
import time

from repro.obs import monotime

#: wire magic for the hello frame; bump the digit on incompatible change
HELLO_MAGIC = "RPTP1"

#: refuse absurd frames before allocating for them (a corrupt or hostile
#: length prefix must not become a multi-GB allocation)
MAX_FRAME_BYTES = 1 << 30

_LEN = struct.Struct("<Q")


class PeerError(Exception):
    """Base class for transport failures."""


class PeerTimeout(PeerError):
    """Nothing arrived within the caller's timeout (a health *miss*)."""


class PeerClosed(PeerError):
    """The link is gone (EOF, reset, or closed queue) — the death path."""


# ---------------------------------------------------------------------------
# chaos injection
# ---------------------------------------------------------------------------

class ChaosState:
    """Thread-safe fault toggles one peer consults on every send/recv.

    ``drop``  — sends are silently discarded until the window expires
    (request loss: the worker never sees them, recovery must come from
    health timeouts + replay/failover, never from the client).
    ``delay`` — every send sleeps first (a slow link, not a dead one).
    ``stall`` — recvs deliver nothing until the window expires even if
    messages are queued (a hung peer / partition that later heals).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._drop_until = 0.0
        self._delay_s = 0.0
        self._delay_until = 0.0
        self._stall_until = 0.0
        self.dropped = 0  # messages eaten by drop windows (observability)

    def drop_for(self, seconds: float) -> None:
        with self._lock:
            self._drop_until = monotime() + float(seconds)

    def delay(self, seconds: float, *, for_s: float = 1e18) -> None:
        with self._lock:
            self._delay_s = max(0.0, float(seconds))
            self._delay_until = monotime() + float(for_s)

    def stall_for(self, seconds: float) -> None:
        with self._lock:
            self._stall_until = monotime() + float(seconds)

    def clear(self) -> None:
        with self._lock:
            self._drop_until = self._delay_until = self._stall_until = 0.0
            self._delay_s = 0.0

    # -- hooks peers call ---------------------------------------------------
    def on_send(self) -> bool:
        """Apply send-side faults; returns False if the message drops."""
        with self._lock:
            now = monotime()
            drop = now < self._drop_until
            delay = self._delay_s if now < self._delay_until else 0.0
            if drop:
                self.dropped += 1
        if delay:
            time.sleep(delay)
        return not drop

    def stalled_until(self) -> float:
        with self._lock:
            return self._stall_until

    def active(self) -> dict:
        with self._lock:
            now = monotime()
            return {"drop": max(0.0, self._drop_until - now),
                    "delay_s": self._delay_s
                    if now < self._delay_until else 0.0,
                    "stall": max(0.0, self._stall_until - now),
                    "dropped": self.dropped}


def _recv_with_stall(raw_recv, chaos: ChaosState | None, held: list,
                     timeout: float | None, bypass_chaos: bool):
    """Shared recv wrapper enforcing stall semantics: a message that
    arrives *during* a stall window (including one that was already in
    flight when the window was armed — the receiver may be blocked in
    the underlying read at arm time) is held, in order, and delivered
    only after the window expires.  ``bypass_chaos`` (the death-drain
    path) skips the wait but still drains held messages first so
    nothing is lost or reordered."""
    if not bypass_chaos:
        timeout = _wait_out_stall(chaos, timeout)
    if held:
        return held.pop(0)
    msg = raw_recv(timeout)
    if (not bypass_chaos and chaos is not None
            and chaos.stalled_until() > monotime()):
        held.append(msg)  # arrived inside the window: withhold it
        raise PeerTimeout("peer stalled")
    return msg


def _wait_out_stall(chaos: ChaosState | None, timeout: float | None
                    ) -> float | None:
    """Sleep through an active stall window (bounded by ``timeout``);
    returns the remaining timeout, or raises :class:`PeerTimeout` if the
    stall consumed it all."""
    if chaos is None:
        return timeout
    until = chaos.stalled_until()
    if until <= 0.0:
        return timeout
    now = monotime()
    if until <= now:
        return timeout
    stall = until - now
    if timeout is not None and stall >= timeout:
        time.sleep(timeout)
        raise PeerTimeout("peer stalled")
    time.sleep(stall)
    return None if timeout is None else max(0.0, timeout - stall)


# ---------------------------------------------------------------------------
# queue peer (same-host: mp.Queue control plane + shm slab payloads)
# ---------------------------------------------------------------------------

class QueuePeer:
    """One side of an ``mp.Queue`` pair; the original same-host link."""

    transport = "shm"
    supports_slabs = True

    def __init__(self, send_q, recv_q, *, chaos: ChaosState | None = None):
        self._send_q = send_q
        self._recv_q = recv_q
        self._held: list = []  # messages withheld by a stall window
        self.chaos = chaos

    def send(self, msg) -> None:
        if self.chaos is not None and not self.chaos.on_send():
            return  # dropped by an injected fault window
        try:
            self._send_q.put(msg)
        except (ValueError, OSError, AssertionError) as e:
            raise PeerClosed(str(e)) from e

    def _raw_recv(self, timeout: float | None):
        try:
            if timeout is None:
                return self._recv_q.get()
            if timeout <= 0.0:
                return self._recv_q.get_nowait()
            return self._recv_q.get(timeout=timeout)
        except queue_mod.Empty as e:
            raise PeerTimeout("no message") from e
        except (EOFError, OSError, ValueError) as e:
            raise PeerClosed(str(e)) from e

    def recv(self, timeout: float | None = None, *,
             bypass_chaos: bool = False):
        return _recv_with_stall(self._raw_recv, self.chaos, self._held,
                                timeout, bypass_chaos)

    def close(self) -> None:
        for q in (self._send_q, self._recv_q):
            try:
                q.close()
                q.cancel_join_thread()
            except Exception:
                pass


# ---------------------------------------------------------------------------
# framed TCP peer
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, payload: bytes) -> None:
    try:
        sock.sendall(_LEN.pack(len(payload)) + payload)
    except OSError as e:
        raise PeerClosed(str(e)) from e


def recv_frame(sock: socket.socket, timeout: float | None = None) -> bytes:
    """One length-prefixed frame; honors ``timeout`` across partial reads."""
    deadline = None if timeout is None else monotime() + timeout
    head = _recv_exact(sock, _LEN.size, deadline)
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME_BYTES:
        raise PeerClosed(f"frame of {n} bytes exceeds cap {MAX_FRAME_BYTES}")
    return _recv_exact(sock, int(n), deadline)


def _recv_exact(sock: socket.socket, n: int, deadline: float | None) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        if deadline is not None:
            left = deadline - monotime()
            if left <= 0.0:
                # mid-frame timeouts leave the stream unframed; the only
                # safe continuation is reconnect, so surface it as closed
                # when bytes were already consumed
                if buf:
                    raise PeerClosed("timeout mid-frame")
                raise PeerTimeout("no frame")
            sock.settimeout(left)
        else:
            sock.settimeout(None)
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout as e:
            if buf:
                raise PeerClosed("timeout mid-frame") from e
            raise PeerTimeout("no frame") from e
        except OSError as e:
            raise PeerClosed(str(e)) from e
        if not chunk:
            raise PeerClosed("EOF")
        buf.extend(chunk)
    return bytes(buf)


class TcpPeer:
    """Pickled messages over length-prefixed TCP frames.

    No slab arena across TCP — plane payloads ride inline in the frame
    (``supports_slabs`` is False, so the parent never hands this peer's
    worker a slab name).
    """

    transport = "tcp"
    supports_slabs = False

    def __init__(self, sock: socket.socket, *,
                 chaos: ChaosState | None = None):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._held: list = []  # messages withheld by a stall window
        self.chaos = chaos
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass

    def send(self, msg) -> None:
        if self.chaos is not None and not self.chaos.on_send():
            return
        payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
        with self._send_lock:
            send_frame(self._sock, payload)

    def _raw_recv(self, timeout: float | None):
        return pickle.loads(recv_frame(self._sock, timeout))

    def recv(self, timeout: float | None = None, *,
             bypass_chaos: bool = False):
        return _recv_with_stall(self._raw_recv, self.chaos, self._held,
                                timeout, bypass_chaos)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# hello handshake + listener (parent side) + worker connect
# ---------------------------------------------------------------------------

def _hello_send(sock: socket.socket, obj: dict) -> None:
    send_frame(sock, json.dumps(obj).encode("utf-8"))


def _hello_recv(sock: socket.socket, timeout: float) -> dict:
    try:
        obj = json.loads(recv_frame(sock, timeout).decode("utf-8"))
    except (ValueError, UnicodeDecodeError) as e:
        raise PeerClosed(f"bad hello: {e}") from e
    if not isinstance(obj, dict) or obj.get("magic") != HELLO_MAGIC:
        raise PeerClosed("bad hello magic")
    return obj


class TcpListener:
    """Parent-side acceptor: one listening socket serves every shard.

    Each worker spawn registers an expected ``(shard, token)``; the
    accept loop handshakes incoming connections, matches the token, and
    hands the authenticated peer to ``on_peer(shard, TcpPeer)``.  A
    reconnecting worker presents the same token and simply replaces its
    previous peer.
    """

    def __init__(self, on_peer, *, host: str = "127.0.0.1",
                 handshake_timeout_s: float = 5.0):
        self._on_peer = on_peer
        self.handshake_timeout_s = float(handshake_timeout_s)
        self._sock = socket.create_server((host, 0))
        self.address = self._sock.getsockname()[:2]
        self._lock = threading.Lock()
        self._expected: dict[int, bytes] = {}
        self._chaos: dict[int, ChaosState] = {}
        self._closed = False
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True, name="peer-accept")
        self._thread.start()

    def expect(self, shard: int, token: bytes,
               chaos: ChaosState | None = None) -> None:
        with self._lock:
            self._expected[int(shard)] = bytes(token)
            if chaos is not None:
                self._chaos[int(shard)] = chaos

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                return  # listener closed
            threading.Thread(target=self._handshake, args=(conn,),
                             daemon=True).start()

    def _handshake(self, conn: socket.socket) -> None:
        try:
            hello = _hello_recv(conn, self.handshake_timeout_s)
            shard = int(hello.get("shard", -1))
            token = bytes.fromhex(str(hello.get("token", "")))
            offered = hello.get("transports") or ["tcp"]
            with self._lock:
                want = self._expected.get(shard)
                chaos = self._chaos.get(shard)
            if want is None or not hmac.compare_digest(want, token):
                _hello_send(conn, {"magic": HELLO_MAGIC, "ok": False,
                                   "error": "unknown peer"})
                conn.close()
                return
            # negotiation: tcp is the only transport a socket can carry,
            # but the reply names the choice so a future same-host
            # upgrade (worker offers "shm") has its seam
            choice = "tcp" if "tcp" in offered else None
            _hello_send(conn, {"magic": HELLO_MAGIC, "ok": choice is not None,
                               "transport": choice})
            if choice is None:
                conn.close()
                return
        except PeerError:
            try:
                conn.close()
            except OSError:
                pass
            return
        self._on_peer(shard, TcpPeer(conn, chaos=chaos))

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout=2.0)


def connect_peer(address: tuple[str, int], shard: int, token: bytes, *,
                 connect_timeout_s: float = 5.0,
                 reconnect_attempts: int = 5,
                 backoff_base_s: float = 0.05,
                 backoff_max_s: float = 1.0) -> TcpPeer:
    """Worker-side connect + hello with bounded exponential backoff.

    Raises :class:`PeerClosed` once every attempt is spent — the worker
    exits and the supervisor's respawn takes over from there.
    """
    last: Exception | None = None
    for attempt in range(max(1, int(reconnect_attempts))):
        if attempt:
            time.sleep(min(backoff_base_s * (2 ** (attempt - 1)),
                           backoff_max_s))
        try:
            sock = socket.create_connection(address,
                                            timeout=connect_timeout_s)
        except OSError as e:
            last = e
            continue
        try:
            _hello_send(sock, {"magic": HELLO_MAGIC, "shard": int(shard),
                               "token": bytes(token).hex(),
                               "transports": ["tcp"]})
            reply = _hello_recv(sock, connect_timeout_s)
            if not reply.get("ok"):
                raise PeerClosed(f"peer refused: {reply.get('error')}")
            sock.settimeout(None)
            return TcpPeer(sock)
        except PeerError as e:
            last = e
            try:
                sock.close()
            except OSError:
                pass
    raise PeerClosed(f"connect to {address} failed after "
                     f"{reconnect_attempts} attempts: {last}")


# ---------------------------------------------------------------------------
# per-owner health state machine
# ---------------------------------------------------------------------------

#: health states, in routing-preference order
ALIVE, REJOINING, SUSPECT, DEAD = "alive", "rejoining", "suspect", "dead"
_RANK = {ALIVE: 0, REJOINING: 1, SUSPECT: 2, DEAD: 3}


class PeerHealth:
    """``alive -> suspect -> dead -> rejoining -> alive``.

    *Misses* (read timeouts, unanswered dispatches) push alive toward
    suspect and suspect toward dead; any delivered reply snaps back to
    alive.  Process death jumps straight to dead; the supervisor marks
    rejoining when the replacement spawns and alive when it reports
    ready.  The router prefers lower :func:`rank` (alive first, dead
    never) when choosing among an owner set.
    """

    def __init__(self, *, suspect_after: int = 1, dead_after: int = 4):
        self.suspect_after = max(1, int(suspect_after))
        self.dead_after = max(self.suspect_after + 1, int(dead_after))
        self._lock = threading.Lock()
        self.state = ALIVE
        self.misses = 0
        self.transitions = 0
        self.since = monotime()

    def _to(self, state: str) -> None:
        if state != self.state:
            self.state = state
            self.transitions += 1
            self.since = monotime()

    def miss(self) -> str:
        with self._lock:
            if self.state == DEAD:
                return self.state
            self.misses += 1
            if self.misses >= self.dead_after:
                self._to(DEAD)
            elif self.misses >= self.suspect_after \
                    and self.state in (ALIVE, SUSPECT):
                self._to(SUSPECT)
            return self.state

    def ok(self) -> None:
        with self._lock:
            self.misses = 0
            self._to(ALIVE)

    def dead(self) -> None:
        with self._lock:
            self._to(DEAD)

    def rejoining(self) -> None:
        with self._lock:
            self.misses = 0
            self._to(REJOINING)

    def rank(self) -> int:
        with self._lock:
            return _RANK[self.state]

    def routable(self) -> bool:
        """Dead owners are never routed to; everything else may be a
        last resort."""
        return self.rank() < _RANK[DEAD]

    def snapshot(self) -> dict:
        with self._lock:
            return {"state": self.state, "misses": self.misses,
                    "transitions": self.transitions,
                    "since_s": round(monotime() - self.since, 3)}
