"""Sharded multi-process query service: escape the GIL for decode-heavy load.

The in-process :class:`~repro.serve.engine.QueryServer` serves every plane
decode inside one Python process; past a few concurrent decode-heavy
clients the GIL is the ceiling (the ROADMAP limiter this module removes).
:class:`ShardedQueryServer` spawns ``n_shards`` worker *processes*, each
owning a full :class:`repro.query.Database` handle (its own mmap + decoded
-plane LRU), and routes every request with a consistent-hash ring keyed by
:meth:`QueryServer._locality_key` — with ``replicas`` (default 2)
successor-distinct owners per key, so each plane is decoded and cached by
a small owner set: the primary serves it in steady state, replicas absorb
hot-plane spill, hedged reads, and failover.

Topology::

    clients -> BatchScheduler (per-shard admission queues)
                 |  serve_window(reqs): one batch message per shard
                 v
             ShardedQueryServer (parent)
               ring: locality_key -> R owners       supervisor: health,
               transport: shm slabs | framed TCP    failover, respawn,
                 |             |             |      replay, hedges
               worker 0      worker 1      worker N-1   (processes)
               Database      Database      Database
               own LRU       own LRU       own LRU

* **routing** — ``profile``/``window`` requests hash on ``(0, pid)``,
  ``stripe``/``value`` on ``(1, ctx)``; the ring is stable under shard-count
  changes (only ~1/N of keys move their primary, and every moved key moves
  to the *new* shard — the classic consistent-hashing property,
  property-tested in ``tests/test_shard.py``).  Among an owner set the
  router prefers health (alive > rejoining > suspect, never dead), then
  least backlog in ``spill_pending`` quanta (hot planes spread over their
  replicas, cold planes stay put), then replica rank.
* **scatter–gather** — summary-space queries (``topk``, ``threshold``,
  ``findings``) fan out over the *live* shard set; each member answers
  the slice of contexts (and, for findings, profiles) the ring assigns
  it under that live set (``within=`` on the select functions, ownership
  masks on the analyzers) and the parent merges partials in the same
  deterministic order the single-process path uses, so results are
  identical to single-process serving for any live set.
* **payloads** — with the same-host ``shm`` transport, plane-sized results
  return through a parent-owned :class:`~repro.runtime.shm.SlabArena`
  (the PR 3 slab transport): the worker serializes straight into the slab
  and ships a tiny descriptor; only results that outgrow their slab fall
  back to the pickled reply path.  Workers never *create* segments, so a
  SIGKILL'd worker cannot leak ``/dev/shm``.  With the ``tcp`` transport
  (:mod:`repro.serve.transport`) payloads ride inline in length-prefixed
  frames — shard groups can live in separate process trees or hosts.
* **fault tolerance** — a per-shard pump thread doubles as supervisor,
  feeding a per-owner health state machine (alive -> suspect -> dead ->
  rejoining).  When a worker dies, in-flight requests with another live
  owner *fail over* immediately (any worker holds the full database, so
  answers stay byte-identical); the rest replay on the respawned
  replacement.  A hung worker (stalled transport, wedged syscall) is
  detected by reply-stall age and killed into the same recovery path.
  Optional **hedged reads** (``hedge_ms``) duplicate a slow primary read
  to the next live replica after a p99-derived delay and take the first
  reply.  A request that outlives ``replay_limit`` respawns (it is
  probably what keeps killing workers) resolves to a structured
  ``QueryError("WorkerLost")`` instead of looping forever.
"""
from __future__ import annotations

import hashlib
import itertools
import multiprocessing as mp
import os
import signal as signal_mod
import sys
import threading
import time
from collections import deque
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout
from dataclasses import dataclass, field

import numpy as np

from repro.core.sparse import SparseMetrics, Trace
from repro.obs import MetricsRegistry, configure, monotime, recorder
from repro.runtime.shm import (SlabArena, read_section, sections_layout,
                               worker_slab, write_section)
from repro.serve.engine import QueryError, QueryRequest, QueryServer
from repro.serve.transport import (ChaosState, PeerClosed, PeerError,
                                   PeerHealth, PeerTimeout, QueuePeer,
                                   TcpListener, connect_peer)

#: summary-space ops served by every shard over its owned contexts and
#: merged in the parent (all other ops route to exactly one shard);
#: "findings" additionally partitions per-rank analyzers by profile
#: ownership
SCATTER_OPS = ("topk", "threshold", "findings")

#: worker replies per response-queue message (latency/throughput balance)
_REPLY_CHUNK = 16

#: ops whose results are plane/array-sized and worth a shm slab; the rest
#: (point values, top-k rows, errors) ride the pickled response queue and
#: must not starve the slab pool
_SLAB_OPS = ("profile", "stripe", "window", "threshold")


def _slab_eligible(req: QueryRequest, scatter: bool) -> bool:
    return not scatter and getattr(req, "op", None) in _SLAB_OPS


# ---------------------------------------------------------------------------
# epoch transitions: many dispatch windows XOR one reopen
# ---------------------------------------------------------------------------

class _RWLock:
    """Reader/writer lock with writer preference.

    Dispatch windows are readers (arbitrarily many in flight); an epoch
    :meth:`ShardedQueryServer.reopen` is the writer.  Writer preference —
    a waiting reopen blocks *new* windows — so a steady query stream can
    never starve an epoch switch, and every window that does run is
    entirely before or entirely after the switch: no batched reply ever
    mixes epochs.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if not self._readers:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


# ---------------------------------------------------------------------------
# consistent-hash ring
# ---------------------------------------------------------------------------

def _hash64(data: bytes) -> int:
    """Stable 64-bit point on the ring (blake2b: no PYTHONHASHSEED drift,
    identical in parent and every worker)."""
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(),
                          "little")


class ConsistentHashRing:
    """Classic vnode hash ring over locality keys, with R-way ownership.

    Each shard owns ``vnodes`` pseudo-random points; a key's **primary**
    owner is the first point clockwise from its own hash, and its
    ``replicas``-way owner set is the first R *distinct* shards met
    walking clockwise (the successor list).  Growing the ring from N to
    N+1 shards only adds points, so the *only* keys that change primary
    owner are the ones the new shard's points capture — an expected
    1/(N+1) of the key space, and every moved key moves to the new
    shard.  The same stability holds per replica rank.
    """

    def __init__(self, n_shards: int, *, vnodes: int = 96,
                 salt: bytes = b"repro-serve-shard", replicas: int = 1):
        self.n_shards = max(1, int(n_shards))
        self.vnodes = max(1, int(vnodes))
        self.salt = bytes(salt)
        self.replicas = max(1, min(int(replicas), self.n_shards))
        pts = sorted(
            (_hash64(b"%s|vnode|%d:%d" % (self.salt, s, v)), s)
            for s in range(self.n_shards) for v in range(self.vnodes))
        self._points = np.array([h for h, _ in pts], dtype=np.uint64)
        self._owner = np.array([s for _, s in pts], dtype=np.int64)

    def _walk_key(self, key: tuple[int, int], need: int) -> list[int]:
        """First ``need`` *distinct* shards clockwise from the key's
        hash point — the successor list that defines replica ownership
        (rank 0 is the classic single owner)."""
        h = _hash64(b"%s|key|%d:%d" % (self.salt, int(key[0]), int(key[1])))
        i = int(np.searchsorted(self._points, np.uint64(h), side="left"))
        n = self._points.size
        need = min(max(1, int(need)), self.n_shards)
        out: list[int] = []
        for j in range(n):
            s = int(self._owner[(i + j) % n])
            if s not in out:
                out.append(s)
                if len(out) == need:
                    break
        return out

    def owners_key(self, key: tuple[int, int]) -> tuple[int, ...]:
        """Locality key -> the R successor-distinct owning shards,
        primary first."""
        return tuple(self._walk_key(key, self.replicas))

    def route_key(self, key: tuple[int, int]) -> int:
        """Locality key ``(group, id)`` -> primary owning shard."""
        h = _hash64(b"%s|key|%d:%d" % (self.salt, int(key[0]), int(key[1])))
        i = int(np.searchsorted(self._points, np.uint64(h), side="left"))
        return int(self._owner[i % self._points.size])

    def route(self, req: QueryRequest) -> int:
        return self.route_key(QueryServer._locality_key(req))

    def owners(self, req: QueryRequest) -> tuple[int, ...]:
        return self.owners_key(QueryServer._locality_key(req))

    def assigned_shard(self, key: tuple[int, int],
                       live=None) -> int:
        """The shard responsible for ``key`` given the ``live`` set: the
        first live shard in successor order (not capped at R — with every
        owner down, responsibility keeps walking, so any non-empty live
        set always yields a total assignment)."""
        if live is None:
            return self.route_key(key)
        live = frozenset(int(s) for s in live)
        for s in self._walk_key(key, self.n_shards):
            if s in live:
                return s
        return self.route_key(key)  # nothing live: degenerate fallback

    def owned_contexts(self, n_contexts: int, shard: int,
                       live=None) -> np.ndarray:
        """Context ids whose ``(1, ctx)`` key is *assigned* to ``shard``
        under the ``live`` set — the ``within=`` set for scatter queries.
        With ``live=None`` this is plain primary ownership; the
        assignment partitions contexts across any live set."""
        return np.array([c for c in range(int(n_contexts))
                         if self.assigned_shard((1, c), live) == int(shard)],
                        dtype=np.int64)

    def owned_context_mask(self, n_contexts: int, shard: int,
                           live=None) -> np.ndarray:
        """Boolean ownership over context ids — the O(1)-lookup ``within=``
        form the worker hands to the select functions per scatter query."""
        mask = np.zeros(int(n_contexts), dtype=bool)
        mask[self.owned_contexts(n_contexts, shard, live)] = True
        return mask

    def owned_profile_mask(self, n_profiles: int, shard: int,
                           live=None) -> np.ndarray:
        """Boolean ownership over profile ids (``(0, pid)`` keys) — the
        per-rank partition the findings analyzers scatter over.  Like
        :meth:`owned_context_mask`, any live set partitions the id space:
        disjoint across members, complete in union."""
        mask = np.zeros(int(n_profiles), dtype=bool)
        owned = [p for p in range(int(n_profiles))
                 if self.assigned_shard((0, p), live) == int(shard)]
        mask[owned] = True
        return mask

    def plane_role(self, store: str, oid: int, shard: int) -> int | None:
        """Replica rank of ``shard`` for a plane (0 = primary, 1.. =
        replica), or None when the shard does not own it.  PMS/trace
        planes follow the profile key, CMS planes the context key."""
        group = 1 if store == "cms" else 0
        owners = self.owners_key((group, int(oid)))
        try:
            return owners.index(int(shard))
        except ValueError:
            return None

    def owns_plane(self, store: str, oid: int, shard: int) -> bool:
        """Warm-plan ownership: any replica rank counts (primaries warm
        hot, replicas warm — see ``warm_priority``)."""
        return self.plane_role(store, oid, shard) is not None

    def warm_priority(self, store: str, oid: int, shard: int, *,
                      replica_scale: float = 0.5) -> float:
        """Warm-plan weight: 1.0 for primary-owned planes, a reduced
        weight for replica-owned ones (they warm after every primary
        plane of equal density), 0.0 for planes the shard never serves
        outside failover."""
        role = self.plane_role(store, oid, shard)
        if role is None:
            return 0.0
        return 1.0 if role == 0 else float(replica_scale)


# ---------------------------------------------------------------------------
# result payload codec (worker -> parent)
# ---------------------------------------------------------------------------
# payload = (mode, kind, data):
#   ("obj",    None,    result)  - small results (floats, topk rows, errors)
#                                  pickled through the response queue
#   ("slab",   "sm",    nbytes)  - SparseMetrics.encode_into the slab
#   ("inline", "sm",    bytes)   - ... that outgrew the slab
#   ("slab",   kind,    meta)    - array sections in the slab; meta is
#                                  ((dtype, count, nbytes), ...) and offsets
#                                  re-derive via sections_layout
#   ("inline", kind,    arrays)  - ... that outgrew the slab
# kind "pair" reassembles a (profiles, values)-style tuple, "trace" a Trace.

def _encode_result(res, slab_buf, slab_bytes: int):
    """Serialize one query result, preferring the shard's shm slab."""
    if isinstance(res, SparseMetrics):
        n = res.encoded_nbytes()
        if slab_buf is not None and n <= slab_bytes:
            res.encode_into(slab_buf, 0)
            return ("slab", "sm", n)
        return ("inline", "sm", res.encode())
    if isinstance(res, Trace):
        kind, arrays = "trace", (res.time, res.ctx)
    elif (isinstance(res, tuple) and len(res) == 2
          and all(isinstance(a, np.ndarray) for a in res)):
        kind, arrays = "pair", res
    else:
        return ("obj", None, res)
    arrays = tuple(np.ascontiguousarray(a) for a in arrays)
    meta = tuple((a.dtype.str, int(a.size), int(a.nbytes)) for a in arrays)
    offs, total = sections_layout([m[2] for m in meta])
    if slab_buf is not None and total <= slab_bytes:
        for a, off in zip(arrays, offs):
            write_section(slab_buf, off, a)
        return ("slab", kind, meta)
    return ("inline", kind, arrays)


def _decode_payload(payload, slab_view):
    """Parent-side inverse of :func:`_encode_result`; always copies out of
    the slab so it can be recycled immediately."""
    mode, kind, data = payload
    if mode == "obj":
        return data
    if kind == "sm":
        buf = bytes(slab_view[:data]) if mode == "slab" else data
        return SparseMetrics.decode(buf)[0]
    if mode == "inline":
        arrays = tuple(data)
    else:
        offs, _ = sections_layout([nb for _, _, nb in data])
        arrays = tuple(read_section(slab_view, off, dt, n, copy=True)
                       for (dt, n, _), off in zip(data, offs))
    return Trace(*arrays) if kind == "trace" else arrays


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

def _serve_scatter(db, owned_ctx: np.ndarray, req: QueryRequest,
                   owned_pid: np.ndarray | None = None):
    """One shard's partial answer to a scatter query, restricted to the
    contexts (and profiles, for findings) it owns; failures mirror
    ``QueryServer.serve_one`` exactly so error results stay byte-identical
    to single-process serving."""
    from repro.query import threshold_contexts, topk_hot_paths
    try:
        params = dict(req.params)
        if req.op == "topk":
            return topk_hot_paths(db, req.metric, k=req.k,
                                  inclusive=req.inclusive, within=owned_ctx,
                                  **params)
        if req.op == "findings":
            # ctx-keyed analyzers take the context mask, pid-keyed ones
            # the profile mask; global aggregates inside each analyzer
            # are shard-invariant, so the partials concat cleanly
            return QueryServer._findings(req, db, within_ctx=owned_ctx,
                                         within_pid=owned_pid)
        return threshold_contexts(
            db, req.metric, min_value=float(params.pop("min_value", 0.0)),
            inclusive=req.inclusive, within=owned_ctx, **params)
    except Exception as e:                                  # noqa: BLE001
        return QueryError(op=str(getattr(req, "op", "?")),
                          error=type(e).__name__, message=str(e))


def _merge_scatter(req: QueryRequest, parts: list):
    """Parent-side merge of per-shard partials, in the exact deterministic
    order the single-process select functions use."""
    for p in parts:
        if isinstance(p, QueryError):
            return p
    if req.op == "topk":
        rows = [h for part in parts for h in part]
        rows.sort(key=lambda h: (-h.value, h.ctx))
        return rows[:max(int(req.k), 0)]
    if req.op == "findings":
        from repro.diagnose.findings import sort_findings
        rows = [f for part in parts for f in part]
        limit = int(dict(req.params).get("limit", 0) or 0)
        return sort_findings(rows, limit or None)
    ctx = np.concatenate([p[0] for p in parts])
    vals = np.concatenate([p[1] for p in parts])
    order = np.lexsort((ctx, -vals))  # value desc, ctx asc tiebreak
    return ctx[order], vals[order]


def _worker_peer(link, shard: int):
    """Build the worker's side of the parent link from its spec:
    ``("queue", req_q, resp_q)`` or ``("tcp", host, port, token_hex)``."""
    if link[0] == "queue":
        _, req_q, resp_q = link
        return QueuePeer(resp_q, req_q)  # worker sends replies, recvs reqs
    _, host, port, token = link
    return connect_peer((host, int(port)), shard, bytes.fromhex(token))


def _shard_worker_main(shard: int, n_shards: int, vnodes: int, salt: bytes,
                       replicas: int, db_dir: str, cache_bytes: int,
                       warm_bytes, server_factory, slab_bytes: int,
                       trace_ring: int, link):
    """Worker loop: own Database, own LRU, serve batches in locality order.

    Module-level (and all-args-picklable) so it runs under any
    multiprocessing start method.  The worker never creates shm segments —
    oversize results fall back to the pickled reply path — so abrupt
    death cannot leak ``/dev/shm``.

    ``link`` picks the parent transport: the same-host queue/shm pair,
    or framed TCP (connect + hello handshake with bounded backoff; see
    :mod:`repro.serve.transport`).  The loop itself is transport-blind.

    The worker runs its own flight recorder (sized by ``trace_ring`` —
    passed explicitly so spawn-start workers match the parent's config)
    and piggybacks freshly recorded spans on every reply chunk, so span
    shipping costs no extra queue round trips and a SIGKILL loses at
    most the spans of the unanswered batch (which the parent's replay
    re-records on the replacement worker anyway).
    """
    import signal

    from repro.query import Database
    from repro.serve.warm import warm_cache

    signal.signal(signal.SIGINT, signal.SIG_IGN)  # parent owns shutdown
    rec = configure(trace_ring)
    rec.default_shard = shard
    try:
        peer = _worker_peer(link, shard)
    except PeerClosed:
        return  # could not reach the parent: let the supervisor respawn
    ring = ConsistentHashRing(n_shards, vnodes=vnodes, salt=salt,
                              replicas=replicas)
    owned = ((lambda store, oid: ring.warm_priority(store, oid, shard))
             if n_shards > 1 else None)
    # scatter assignment masks are a function of (member, live-set) and
    # the open epoch's context/profile counts — tiny dicts, rebuilt per
    # epoch
    masks: dict[tuple, np.ndarray] = {}

    def _mask(d, member: int, live: tuple):
        key = (member, live)
        m = masks.get(key)
        if m is None:
            m = ring.owned_context_mask(d.n_contexts, member, live or None)
            masks[key] = m
        return m

    def _pmask(d, member: int, live: tuple):
        key = ("pid", member, live)
        m = masks.get(key)
        if m is None:
            m = ring.owned_profile_mask(d.n_profiles, member, live or None)
            masks[key] = m
        return m

    def _open(path):
        d = Database(path, cache_bytes=cache_bytes)
        srv = (server_factory or QueryServer)(d)
        masks.clear()
        report = None
        if warm_bytes is None or warm_bytes > 0:
            report = warm_cache(d, warm_bytes, owned=owned)
        return d, srv, report

    db, server, warm_report = _open(db_dir)
    peer.send(("ready", {"shard": shard, "pid": os.getpid(),
                         "warm": warm_report}))
    while True:
        try:
            msg = peer.recv()
        except PeerTimeout:
            continue
        except PeerClosed:
            if link[0] != "tcp":
                break
            # transport loss, not shutdown: reconnect with bounded
            # backoff and re-handshake; exhausting the budget exits the
            # worker and hands recovery to the supervisor's respawn
            try:
                peer = _worker_peer(link, shard)
            except PeerClosed:
                break
            continue
        if msg is None:
            break
        if isinstance(msg, tuple) and msg and msg[0] == "reopen":
            # epoch switch: messages are processed serially, so every
            # batch queued before this one was answered from the old
            # epoch — closing here is safe because every result path
            # copies out of the mmap before replying.  A fresh Database
            # means a fresh (empty) plane LRU: cache invalidation is
            # structural, not key-by-key.
            new_dir = msg[1]
            db.close()
            db, server, warm_report = _open(new_dir)
            peer.send(("reopened", {"shard": shard, "pid": os.getpid(),
                                    "dir": new_dir, "warm": warm_report}))
            continue
        items = msg  # [(key, QueryRequest, slab | None, scatter), ...]
        # plane-less ops (group 2: top-k/threshold partials) first — they
        # are barrier legs of scatter-gather merges, so answering them
        # early keeps sibling shards' merges from waiting out this
        # shard's plane work; then plane ops in locality order
        order = sorted(range(len(items)),
                       key=lambda i: (lambda k: (k[0] != 2, k))(
                           QueryServer._locality_key(items[i][1])))
        replies = []
        for i in order:  # every hot plane decodes once per batch
            key, req, slab_name, scatter = items[i]
            tid = getattr(req, "trace_id", None) or ""
            try:
                if scatter and req.op in SCATTER_OPS and n_shards > 1:
                    # scatter partials carry (member, live-set): answer
                    # for the member's slice of the live assignment (the
                    # member is this shard unless the partial failed
                    # over here).  They bypass serve_one (and its decode
                    # span), so time them here.
                    member, live = scatter
                    t0 = monotime()
                    pmask = (_pmask(db, member, live)
                             if req.op == "findings" else None)
                    res = _serve_scatter(db, _mask(db, member, live), req,
                                         owned_pid=pmask)
                    if rec.enabled:
                        rec.record("decode", str(req.op), t0, monotime() - t0,
                                   trace_id=tid)
                else:
                    res = server.serve_one(req)
                slab_buf = (worker_slab(slab_name).buf
                            if slab_name is not None else None)
                t0 = monotime()
                payload = _encode_result(res, slab_buf, slab_bytes)
                if rec.enabled:
                    rec.record("encode", str(getattr(req, "op", "?")), t0,
                               monotime() - t0, trace_id=tid)
            except Exception as e:                          # noqa: BLE001
                payload = ("obj", None, QueryError(
                    op=str(getattr(req, "op", "?")),
                    error=type(e).__name__, message=str(e)))
            replies.append((key, payload))
            # chunked responses: the transport round trip amortizes over
            # a chunk instead of being paid per request, while early
            # results still stream back before the batch finishes (a
            # whole-batch reply would stall closed-loop clients and
            # drain the pipeline).  Spans recorded since the last chunk
            # ride the same message.
            if len(replies) >= _REPLY_CHUNK:
                peer.send(("res", replies, rec.drain_outbox()))
                replies = []
        tail = rec.drain_outbox()
        if replies or tail:
            peer.send(("res", replies, tail))
    db.close()


# ---------------------------------------------------------------------------
# parent: shard records, supervisor, scatter-gather
# ---------------------------------------------------------------------------

@dataclass
class _Pending:
    req: QueryRequest
    future: Future
    slab: str | None
    scatter: object  # False, or (member, live-set tuple) for partials
    replays: int = 0
    t0: float = 0.0  # monotime() at (re-)dispatch, drives stall detection


@dataclass
class _Shard:
    index: int
    arena: SlabArena | None
    free_slabs: list[str]
    chaos: ChaosState = field(default_factory=ChaosState)
    health: PeerHealth = field(default_factory=PeerHealth)
    lock: threading.Lock = field(default_factory=threading.Lock)
    pending: dict[int, _Pending] = field(default_factory=dict)
    proc: mp.process.BaseProcess | None = None
    peer: object = None          # parent side of the worker link
    backlog: list = field(default_factory=list)  # msgs awaiting a peer
    slab_ok: bool = True
    ready: threading.Event = field(default_factory=threading.Event)
    reopen_ack: threading.Event = field(default_factory=threading.Event)
    warm: dict | None = None
    deaths: int = 0
    last_reply_t: float = 0.0
    last_miss_t: float = 0.0


class ShardedQueryServer:
    """Multi-process drop-in for :class:`QueryServer` over one database.

    Exposes the same serving surface the scheduler and HTTP layer consume
    (``serve_one`` / ``serve`` / ``_locality_key``) plus the shard-aware
    hooks the :class:`~repro.serve.scheduler.BatchScheduler` uses when
    present (``n_shards``, ``shard_of``, ``serve_window``).

    ``cache_bytes``/``warm_bytes`` are *per worker*: sharding scales cache
    capacity with compute; with ``replicas`` > 1 each plane has R owners
    (primary warmed hot, replicas warm), so a hot plane's decode load can
    spread across its owner set and any single owner's death leaves live
    replicas to fail over to.

    Replication/failover knobs:

    * ``replicas`` — R-way successor-distinct ownership (default 2;
      capped at ``n_shards``).
    * ``transport`` — ``"shm"`` (mp.Queue control + shm slab payloads,
      same host) or ``"tcp"`` (length-prefixed frames, workers connect
      back with a per-spawn token; payloads ride inline).
    * ``hedge_ms`` — when set, single-owner reads fire a *hedge* to the
      next live replica after ``max(hedge_ms, observed p99)`` and the
      first reply wins (replicas serve byte-identical answers within an
      epoch).  ``None`` disables hedging.
    * ``spill_pending`` — backlog quantum for replica read-scaling: the
      router prefers the primary until its pending depth exceeds a live
      replica's by a full quantum, then spills (0 pins reads to the
      primary unless it is unhealthy).
    * ``suspect_after_s`` / ``hang_kill_s`` — stall thresholds driving
      the per-owner health machine: a shard with dispatched-but-
      unanswered work older than ``suspect_after_s`` takes health
      *misses* (alive -> suspect -> dead for routing); older than
      ``hang_kill_s`` it is presumed hung and SIGKILLed so the
      respawn/replay/failover path recovers its in-flight work.
    """

    def __init__(self, db_dir: str, n_shards: int, *,
                 cache_bytes: int = 64 << 20, warm_bytes: int | None = 0,
                 n_slabs: int = 32, slab_bytes: int = 4 << 20,
                 vnodes: int = 96, server_factory=None,
                 replay_limit: int = 3, dispatch_timeout_s: float = 60.0,
                 start_timeout_s: float = 120.0, mp_context: str | None = None,
                 trace_ring: int | None = None, replicas: int = 2,
                 transport: str = "shm", hedge_ms: float | None = None,
                 spill_pending: int = 4, suspect_after_s: float = 1.0,
                 hang_kill_s: float = 30.0):
        if db_dir is None:
            raise ValueError("sharded serving needs a database directory "
                             "(explicit pms_path handles cannot be re-opened "
                             "by workers)")
        self.db_dir = str(db_dir)
        self.n_shards = max(1, int(n_shards))
        self.cache_bytes = int(cache_bytes)
        self.warm_bytes = warm_bytes
        self.n_slabs = max(1, int(n_slabs))
        self.slab_bytes = max(1 << 12, int(slab_bytes))
        self.ring = ConsistentHashRing(self.n_shards, vnodes=vnodes,
                                       replicas=replicas)
        self.replicas = self.ring.replicas
        if transport not in ("shm", "tcp"):
            raise ValueError(f"unknown transport {transport!r} "
                             f"(expected 'shm' or 'tcp')")
        self.transport = transport
        self.hedge_ms = None if hedge_ms is None else max(0.0,
                                                          float(hedge_ms))
        self.spill_pending = max(0, int(spill_pending))
        self.suspect_after_s = max(0.05, float(suspect_after_s))
        self.hang_kill_s = max(0.0, float(hang_kill_s))
        self.server_factory = server_factory
        self.replay_limit = int(replay_limit)
        self.dispatch_timeout_s = float(dispatch_timeout_s)
        self.start_timeout_s = float(start_timeout_s)

        # value lookups are served from a CMS stripe when that store
        # exists, so they route context-major like stripes; a PMS-only
        # database answers them from the *profile* plane instead — route
        # them profile-major there, or every shard would decode (and
        # warm) the same PMS planes the ring assigned to one owner
        from repro.query.database import CMS_NAME
        self._has_cms = os.path.exists(os.path.join(self.db_dir, CMS_NAME))

        if mp_context is None:
            mp_context = os.environ.get("REPRO_MP_CONTEXT") or None
        if mp_context is None:
            # same tradeoff as runtime.processes: fork on Linux (spawn
            # re-imports __main__), REPRO_MP_CONTEXT=forkserver opts out
            methods = mp.get_all_start_methods()
            mp_context = ("fork" if sys.platform == "linux"
                          and "fork" in methods else "spawn")
        self._ctx = mp.get_context(mp_context)

        # flight-recorder ring size for the worker processes; None
        # inherits this (parent) process's configured capacity, so one
        # `configure()` at the front covers the fleet under any mp start
        # method (spawn workers don't inherit parent globals)
        self.trace_ring = (recorder().capacity if trace_ring is None
                           else max(0, int(trace_ring)))

        self._shards: list[_Shard] = []
        self._pumps: list[threading.Thread] = []
        self._listener: TcpListener | None = None
        self._seq = itertools.count()
        self._started = False
        self._closed = False
        self._stats_lock = threading.Lock()
        # recent dispatch->reply latencies (seconds) feeding the
        # p99-derived hedge delay; GIL-atomic appends, no lock needed
        self._lat: "deque[float]" = deque(maxlen=512)
        self.obs = MetricsRegistry()
        self._stats = self.obs.group(
            "shard", {"dispatched": 0, "completed": 0, "respawns": 0,
                      "worker_lost": 0, "replayed": 0, "scatter_queries": 0,
                      "deduped": 0, "slab_payloads": 0,
                      "inline_payloads": 0, "reopens": 0,
                      "reopen_last_s": 0.0, "failovers": 0, "hedges": 0,
                      "hedge_wins": 0, "health_misses": 0, "hung_kills": 0},
            gauges=("reopen_last_s",))
        self._rw = _RWLock()  # windows are readers, reopen() the writer
        # epoch generation guards late hedges: a hedge armed before a
        # reopen must not dispatch after it (its primary answered — or
        # will replay — on the old epoch)
        self._epoch_gen = 0
        self._reopening = False
        self._reopen_dir: str | None = None

    # make the scheduler's locality sort work unchanged
    _locality_key = staticmethod(QueryServer._locality_key)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ShardedQueryServer":
        if self._started:
            return self
        self._started = True
        try:
            if self.transport == "tcp":
                self._listener = TcpListener(self._on_peer)
            for s in range(self.n_shards):
                if self.transport == "tcp":
                    # no shm slabs across TCP: payloads ride inline in
                    # the frame, so no arena is allocated at all
                    arena, free, slab_ok = None, [], False
                else:
                    arena = SlabArena(self.n_slabs, self.slab_bytes)
                    free, slab_ok = list(arena._free), True
                shard = _Shard(index=s, arena=arena, free_slabs=free,
                               slab_ok=slab_ok)
                self._shards.append(shard)
                self._spawn_locked(shard)
            for shard in self._shards:
                pump = threading.Thread(target=self._pump_loop,
                                        args=(shard.index,), daemon=True,
                                        name=f"shard-pump-{shard.index}")
                pump.start()
                self._pumps.append(pump)
            deadline = monotime() + self.start_timeout_s
            for shard in self._shards:
                # re-read shard.ready each poll: a worker that crashes
                # during startup is respawned by the supervisor with a
                # FRESH Event, and waiting on the original object would
                # miss the replacement's ready signal
                while not shard.ready.wait(0.1):
                    if monotime() > deadline:
                        raise RuntimeError(
                            f"shard {shard.index} worker failed to become "
                            f"ready within {self.start_timeout_s:.0f}s")
        except BaseException:
            self.close()
            raise
        return self

    def _spawn_locked(self, shard: _Shard) -> None:
        """(Re)create one worker; caller holds ``shard.lock`` on respawn."""
        if self.transport == "tcp":
            # per-spawn token: the worker (and only it) can present it,
            # and a stale pre-respawn connection can never be re-adopted
            token = os.urandom(16)
            self._listener.expect(shard.index, token, shard.chaos)
            shard.peer = None  # installed by the accept loop on hello
            host, port = self._listener.address
            link = ("tcp", host, port, token.hex())
        else:
            req_q, resp_q = self._ctx.Queue(), self._ctx.Queue()
            shard.peer = QueuePeer(req_q, resp_q, chaos=shard.chaos)
            link = ("queue", req_q, resp_q)
        shard.ready = threading.Event()
        shard.proc = self._ctx.Process(
            target=_shard_worker_main,
            args=(shard.index, self.n_shards, self.ring.vnodes,
                  self.ring.salt, self.replicas, self.db_dir,
                  self.cache_bytes, self.warm_bytes, self.server_factory,
                  self.slab_bytes, self.trace_ring, link),
            daemon=True, name=f"repro-shard-{shard.index}")
        shard.proc.start()

    def _on_peer(self, shard_idx: int, peer) -> None:
        """TCP accept path: install (or replace, on worker reconnect) a
        shard's authenticated peer and flush anything queued while the
        link was down."""
        if not (0 <= shard_idx < len(self._shards)):
            peer.close()
            return
        shard = self._shards[shard_idx]
        with shard.lock:
            old, shard.peer = shard.peer, peer
            backlog, shard.backlog = shard.backlog, []
            for n, msg in enumerate(backlog):
                try:
                    peer.send(msg)
                except PeerClosed:
                    # link died again already: keep the unsent tail for
                    # the next reconnect
                    shard.backlog = backlog[n:] + shard.backlog
                    break
        if old is not None:
            old.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for shard in self._shards:
            with shard.lock:
                if shard.peer is not None:
                    try:
                        shard.peer.send(None)
                    except PeerClosed:
                        pass
        for pump in self._pumps:
            pump.join(timeout=10.0)
        leftovers: list[_Pending] = []
        for shard in self._shards:
            with shard.lock:
                leftovers.extend(shard.pending.values())
                shard.pending.clear()
            if shard.proc is not None:
                shard.proc.join(timeout=5.0)
                if shard.proc.is_alive():
                    shard.proc.terminate()
                    shard.proc.join(timeout=2.0)
                if shard.proc.is_alive():
                    shard.proc.kill()
                    shard.proc.join(timeout=2.0)
            if shard.peer is not None:
                shard.peer.close()
            if shard.arena is not None:
                shard.arena.close()
        if self._listener is not None:
            self._listener.close()
        for p in leftovers:
            if not p.future.done():
                try:
                    p.future.set_exception(
                        RuntimeError("sharded query server closed"))
                except Exception:
                    pass

    def __enter__(self) -> "ShardedQueryServer":
        return self.start()

    def __exit__(self, *a) -> None:
        self.close()

    # -- epoch transitions ----------------------------------------------------
    def reopen(self, db_dir: str) -> dict:
        """Move every worker to a new database directory without restart.

        Takes the window lock exclusively (writer preference — a query
        stream cannot starve the switch), sends each worker a ``reopen``
        control message, and waits for all acks.  Worker queues are FIFO
        and processed serially, so every batch dispatched before this
        call is answered from the *old* epoch and every batch after it
        from the new one — the window lock makes that boundary cover
        whole dispatch windows, so no batched reply mixes epochs.

        A worker that dies mid-switch is respawned by the supervisor on
        the previous directory (replays land on the old epoch — the
        documented recovery limit) and the reopen message is re-sent, so
        the switch still converges.  While the switch is in flight the
        supervisor also suppresses cross-replica failover (death
        recovery replays to the same ring position instead): a partial
        failed over to a shard that already acked would be answered
        from the *new* epoch while its sibling partials came from the
        old one.  The epoch generation bump at the end retires any
        armed-but-unfired hedges for the same reason.
        """
        if not self._started:
            raise RuntimeError("sharded query server is not started")
        if self._closed:
            raise RuntimeError("sharded query server is closed")
        from repro.query.database import CMS_NAME
        new_dir = str(db_dir)
        t0 = monotime()
        self._rw.acquire_write()
        self._reopen_dir = new_dir
        self._reopening = True
        try:
            for shard in self._shards:
                with shard.lock:
                    shard.reopen_ack = threading.Event()
                    self._send_locked(shard, ("reopen", new_dir))
            deadline = monotime() + self.start_timeout_s
            for shard in self._shards:
                seen = shard.deaths
                while not shard.reopen_ack.wait(0.1):
                    if self._closed:
                        raise RuntimeError("sharded query server closed "
                                           "during reopen")
                    with shard.lock:
                        if shard.deaths != seen:
                            # the worker died mid-switch; its replacement
                            # came up on the old directory — re-send
                            seen = shard.deaths
                            self._send_locked(shard, ("reopen", new_dir))
                    if monotime() > deadline:
                        raise RuntimeError(
                            f"shard {shard.index} did not ack reopen "
                            f"within {self.start_timeout_s:.0f}s")
            # respawns-after-death from here on land on the new epoch
            self.db_dir = new_dir
            self._has_cms = os.path.exists(os.path.join(new_dir, CMS_NAME))
            self._epoch_gen += 1
            dt = monotime() - t0
            with self._stats_lock:
                self._stats["reopens"] += 1
                self._stats["reopen_last_s"] = dt
            return {"dir": new_dir, "seconds": dt}
        finally:
            self._reopening = False
            self._reopen_dir = None
            self._rw.release_write()

    @staticmethod
    def _send_locked(shard: _Shard, msg) -> None:
        """Send on a shard's peer (caller holds ``shard.lock``); with the
        link down (TCP reconnect window) the message queues in the
        backlog and flushes, in order, when the peer is re-installed."""
        if shard.peer is None:
            shard.backlog.append(msg)
            return
        try:
            shard.peer.send(msg)
        except PeerClosed:
            shard.backlog.append(msg)

    # -- routing -------------------------------------------------------------
    def _owners_of(self, req: QueryRequest) -> tuple[int, ...]:
        """R-way owner set for a request, primary first."""
        if getattr(req, "op", None) == "value" and not self._has_cms:
            # PMS-only database: the plane a value lookup touches is the
            # profile plane, so route to its owners
            try:
                return self.ring.owners_key((0, int(req.pid or 0)))
            except (TypeError, ValueError):
                pass
        return self.ring.owners(req)

    def _pick_owner(self, owners: tuple[int, ...]) -> int:
        """Route among an owner set: healthiest state first, then least
        backlog (quantized by ``spill_pending`` so small depth noise
        never breaks cache locality), then replica rank.  A fully-dead
        owner set degenerates to the primary — its pendings replay
        through the supervisor anyway."""
        best, best_key = owners[0], None
        for rank, o in enumerate(owners):
            health = self._shards[o].health.rank()
            if health >= 3:  # dead: never route
                continue
            bucket = (len(self._shards[o].pending) // self.spill_pending
                      if self.spill_pending else 0)
            key = (health, bucket, rank)
            if best_key is None or key < best_key:
                best, best_key = o, key
        return best

    def shard_of(self, req: QueryRequest) -> int | None:
        """Target shard for a request; ``None`` means scatter."""
        op = getattr(req, "op", None)
        if self.n_shards > 1 and op in SCATTER_OPS:
            return None
        owners = self._owners_of(req)
        if len(owners) == 1 or not self._shards:
            return owners[0]
        return self._pick_owner(owners)

    def _live_set(self) -> tuple[int, ...]:
        """Shards a scatter query fans out over (every non-dead shard;
        the assignment mask partitions contexts across exactly this
        set).  All-dead degenerates to everyone — the supervisor is
        about to respawn them regardless."""
        live = tuple(s.index for s in self._shards
                     if s.health.rank() < 3)
        return live or tuple(range(self.n_shards))

    def worker_pids(self) -> list[int]:
        return [s.proc.pid for s in self._shards if s.proc is not None]

    # -- chaos hooks (tests + benchmarks/serve_load.py --chaos) ---------------
    def kill_worker(self, shard_idx: int) -> int | None:
        """SIGKILL one shard's worker process (fault injection)."""
        shard = self._shards[shard_idx]
        proc = shard.proc
        if proc is None or proc.pid is None:
            return None
        try:
            os.kill(proc.pid, signal_mod.SIGKILL)
        except (OSError, ProcessLookupError):
            return None
        return proc.pid

    def inject_fault(self, shard_idx: int, kind: str, seconds: float, *,
                     delay_s: float = 0.02) -> None:
        """Arm a transport fault window on one peer: ``drop`` (requests
        vanish), ``delay`` (each send sleeps ``delay_s``), or ``stall``
        (replies stop being delivered — a hung peer)."""
        chaos = self._shards[shard_idx].chaos
        if kind == "drop":
            chaos.drop_for(seconds)
        elif kind == "delay":
            chaos.delay(delay_s, for_s=seconds)
        elif kind == "stall":
            chaos.stall_for(seconds)
        else:
            raise ValueError(f"unknown fault kind {kind!r}")

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self, shard_idx: int,
                  reqs: list[tuple[QueryRequest, object]]) -> list[Future]:
        """Send ``[(request, scatter), ...]`` to one worker as a single
        batch message; returns one Future per entry."""
        shard = self._shards[shard_idx]
        items, futs = [], []
        now = monotime()
        with shard.lock:
            if self._closed:
                raise RuntimeError("sharded query server is closed")
            for req, scatter in reqs:
                key = next(self._seq)
                slab = (shard.free_slabs.pop()
                        if shard.free_slabs and shard.slab_ok
                        and _slab_eligible(req, scatter)
                        else None)
                p = _Pending(req, Future(), slab, scatter, t0=now)
                shard.pending[key] = p
                items.append((key, req, slab, scatter))
                futs.append(p.future)
            self._send_locked(shard, items)
        with self._stats_lock:
            self._stats["dispatched"] += len(items)
        return futs

    # -- hedged reads ---------------------------------------------------------
    def _hedge_delay_s(self) -> float:
        """p99 of recent dispatch latencies, floored at ``hedge_ms``: a
        hedge should fire only when the primary is off its own tail."""
        base = (self.hedge_ms or 0.0) / 1e3
        lat = sorted(self._lat)
        if lat:
            base = max(base, lat[int(0.99 * (len(lat) - 1))])
        return max(base, 1e-3)

    def _maybe_hedge(self, req: QueryRequest, primary: int,
                     fut: Future) -> Future:
        """Wrap a single-owner dispatch with an optional hedge: if the
        primary has not answered after a p99-derived delay, the same
        request is dispatched to the next live replica and the first
        reply wins (within an epoch every replica serves byte-identical
        answers, so the winner's identity is unobservable).  The loser's
        reply is still drained normally — it just finds the output
        future already resolved."""
        if self.hedge_ms is None or self.replicas < 2:
            return fut
        owners = self._owners_of(req)
        alts = [o for o in owners
                if o != primary and self._shards[o].health.rank() < 2]
        if not alts:
            return fut
        alt = alts[0]
        out: Future = Future()

        def relay(f: Future, hedged: bool) -> None:
            if out.done():
                return
            exc = f.exception()
            try:
                if exc is not None:
                    out.set_exception(exc)
                else:
                    out.set_result(f.result())
            except Exception:
                return  # lost the race to the other leg
            if hedged:
                with self._stats_lock:
                    self._stats["hedge_wins"] += 1

        fut.add_done_callback(lambda f: relay(f, False))
        gen = self._epoch_gen

        def fire() -> None:
            if out.done() or self._closed:
                return
            # take the window lock as a reader: if a reopen is waiting
            # or running, this blocks until it finishes and the epoch
            # generation check below retires the hedge (the primary
            # answers — or replays — entirely on the old epoch)
            self._rw.acquire_read()
            try:
                if self._epoch_gen != gen or out.done():
                    return
                try:
                    [hfut] = self._dispatch(alt, [(req, False)])
                except RuntimeError:
                    return
                with self._stats_lock:
                    self._stats["hedges"] += 1
                rec = recorder()
                if rec.enabled:
                    rec.record("hedge", str(getattr(req, "op", "?")),
                               monotime(), 0.0,
                               trace_id=getattr(req, "trace_id", None) or "",
                               attrs={"primary": primary, "hedge": alt})
                hfut.add_done_callback(lambda f: relay(f, True))
            finally:
                self._rw.release_read()

        timer = threading.Timer(self._hedge_delay_s(), fire)
        timer.daemon = True
        timer.start()
        out.add_done_callback(lambda _f: timer.cancel())
        return out

    def _await(self, fut: Future, req: QueryRequest):
        try:
            return fut.result(timeout=self.dispatch_timeout_s)
        except FutureTimeout:
            return QueryError(op=str(getattr(req, "op", "?")),
                              error="ShardTimeout",
                              message=f"no shard response within "
                                      f"{self.dispatch_timeout_s:.0f}s")
        except Exception as e:                              # noqa: BLE001
            return QueryError(op=str(getattr(req, "op", "?")),
                              error=type(e).__name__, message=str(e))

    # -- serving surface ------------------------------------------------------
    @staticmethod
    def _dedupe_key(req: QueryRequest):
        """Hashable identity of a request, or None if it has one-off
        unhashable params (then it just doesn't coalesce)."""
        try:
            key = (req.op, req.pid, req.ctx, req.metric, req.inclusive,
                   req.k, req.t0, req.t1,
                   tuple(sorted(req.params.items())))
            hash(key)  # params values may be unhashable (JSON lists)
            return key
        except TypeError:
            return None

    @staticmethod
    def _merged_future(req: QueryRequest, parts: list[Future]) -> Future:
        """A Future that resolves to the scatter-gather merge once every
        per-shard partial has resolved (merge runs on the last pump
        thread to deliver — never blocks a caller)."""
        merged: Future = Future()
        remaining = [len(parts)]
        lock = threading.Lock()

        def on_done(_f: Future) -> None:
            with lock:
                remaining[0] -= 1
                if remaining[0]:
                    return
            t0 = monotime()
            try:
                vals = []
                for f in parts:
                    exc = f.exception()
                    if exc is not None:
                        vals.append(QueryError(
                            op=str(getattr(req, "op", "?")),
                            error=type(exc).__name__, message=str(exc)))
                    else:
                        vals.append(f.result())
                res = _merge_scatter(req, vals)
            except Exception as e:                          # noqa: BLE001
                res = QueryError(op=str(getattr(req, "op", "?")),
                                 error=type(e).__name__, message=str(e))
            rec = recorder()
            if rec.enabled:
                rec.record("merge", str(getattr(req, "op", "?")), t0,
                           monotime() - t0,
                           trace_id=getattr(req, "trace_id", None) or "",
                           attrs={"parts": len(parts)})
            if not merged.done():
                merged.set_result(res)

        for f in parts:
            f.add_done_callback(on_done)
        return merged

    def serve_window_async(self, reqs: list[QueryRequest]) -> list[Future]:
        """Dispatch a batch and return one Future per request slot.

        One message per shard per window (the worker re-sorts its slice
        in plane-locality order and streams replies back in chunks);
        scatter ops ride along in every shard's message and resolve
        through a merge future.  Identical requests in a window are
        *coalesced* before dispatch — the cross-process analog of "the
        cache does the batching": a burst of clients asking for the same
        hot plane costs one worker response (and one shm payload), and
        every duplicate slot shares the same Future, exactly like LRU
        hits share a decoded plane in-process.
        """
        if not self._started:
            raise RuntimeError("sharded query server is not started")
        self._rw.acquire_read()
        try:
            return self._serve_window_async_locked(reqs)
        finally:
            self._rw.release_read()

    def _serve_window_async_locked(self,
                                   reqs: list[QueryRequest]) -> list[Future]:
        alias = list(range(len(reqs)))
        reps: dict[object, int] = {}
        for i, req in enumerate(reqs):
            k = self._dedupe_key(req)
            if k is not None:
                alias[i] = reps.setdefault(k, i)
        n_unique = len(set(alias))
        per_shard: list[list[tuple[int, QueryRequest, object]]] = \
            [[] for _ in range(self.n_shards)]
        n_scatter = 0
        live = None
        for i, req in enumerate(reqs):
            if alias[i] != i:
                continue  # a duplicate slot shares its representative
            s = self.shard_of(req)
            if s is None:
                # scatter over the current live set: each member answers
                # its own slice of the (member, live) assignment, which
                # partitions contexts across exactly the live shards
                if live is None:
                    live = self._live_set()
                n_scatter += 1
                for t in live:
                    per_shard[t].append((i, req, (t, live)))
            else:
                per_shard[s].append((i, req, False))
        with self._stats_lock:
            self._stats["scatter_queries"] += n_scatter
            self._stats["deduped"] += len(reqs) - n_unique
        futs: list[Future | None] = [None] * len(reqs)
        scatter_parts: dict[int, list[Future]] = {}
        for s, items in enumerate(per_shard):
            if not items:
                continue
            for (i, req, scatter), fut in zip(
                    items, self._dispatch(s, [(r, sc)
                                              for _, r, sc in items])):
                if scatter:
                    scatter_parts.setdefault(i, []).append(fut)
                else:
                    futs[i] = self._maybe_hedge(req, s, fut)
        for i, parts in scatter_parts.items():
            futs[i] = self._merged_future(reqs[i], parts)
        for i, j in enumerate(alias):
            if j != i:
                futs[i] = futs[j]
        return futs

    def serve_window(self, reqs: list[QueryRequest]) -> list:
        """Blocking :meth:`serve_window_async`: results in request order,
        failures as inline :class:`QueryError` values."""
        futs = self.serve_window_async(reqs)
        return [self._await(f, r) for f, r in zip(futs, reqs)]

    def serve(self, reqs: list[QueryRequest]) -> list:
        return self.serve_window(reqs)

    def serve_one(self, req: QueryRequest):
        return self.serve_window([req])[0]

    def submit(self, req: QueryRequest):
        """Single-request convenience mirroring ``QueryServer.submit``:
        raises structured failures instead of returning them."""
        res = self.serve_one(req)
        if isinstance(res, QueryError):
            raise RuntimeError(f"{res.error}: {res.message} (op={res.op})")
        return res

    # -- supervisor -----------------------------------------------------------
    def _pump_loop(self, shard_idx: int) -> None:
        shard = self._shards[shard_idx]
        while not self._closed:
            peer, proc = shard.peer, shard.proc
            if peer is None:
                # TCP worker (re)connecting; the accept loop installs
                # the peer when the hello lands
                time.sleep(0.02)
                if proc is not None and not proc.is_alive() \
                        and not self._closed:
                    self._handle_death(shard)
                continue
            try:
                msg = peer.recv(timeout=0.1)
            except PeerTimeout:
                if self._closed:
                    continue
                if proc is not None and not proc.is_alive():
                    self._handle_death(shard)
                else:
                    self._check_stall(shard)
                continue
            except PeerClosed:
                if self._closed:
                    continue
                if proc is not None and not proc.is_alive():
                    self._handle_death(shard)
                else:
                    # link lost but the worker lives: a TCP reconnect is
                    # in flight (the accept loop will replace the peer)
                    time.sleep(0.02)
                continue
            self._handle_msg(shard, msg)

    def _check_stall(self, shard: _Shard) -> None:
        """Idle-tick health: dispatched work with no reply for
        ``suspect_after_s`` accumulates misses (alive -> suspect ->
        dead for routing); past ``hang_kill_s`` the worker is presumed
        hung (stalled transport, wedged syscall) and killed so the
        death path can replay/fail over its in-flight requests."""
        now = monotime()
        with shard.lock:
            if not shard.pending:
                return
            oldest = min(p.t0 for p in shard.pending.values())
        stalled_since = max(oldest, shard.last_reply_t)
        age = now - stalled_since
        if age < self.suspect_after_s:
            return
        if now - shard.last_miss_t >= self.suspect_after_s:
            shard.last_miss_t = now
            shard.health.miss()
            with self._stats_lock:
                self._stats["health_misses"] += 1
        if self.hang_kill_s and age >= self.hang_kill_s:
            proc = shard.proc
            if proc is not None and proc.is_alive() and proc.pid:
                with self._stats_lock:
                    self._stats["hung_kills"] += 1
                try:
                    os.kill(proc.pid, signal_mod.SIGKILL)
                except (OSError, ProcessLookupError):
                    pass

    def _handle_msg_locked(self, shard: _Shard, msg
                           ) -> list[tuple[Future, object]]:
        """Decode one worker message; caller holds ``shard.lock`` and
        resolves the returned futures *after* releasing it."""
        shard.last_reply_t = monotime()
        shard.health.ok()
        if msg[0] == "ready":
            shard.warm = msg[1]
            shard.ready.set()
            return []
        if msg[0] == "reopened":
            shard.warm = msg[1].get("warm")
            shard.reopen_ack.set()
            return []
        if len(msg) > 2 and msg[2]:
            # spans the worker piggybacked on this reply chunk
            recorder().extend(msg[2])
        resolved: list[tuple[Future, object]] = []
        slab_n = inline_n = 0
        now = monotime()
        for key, payload in msg[1]:
            p = shard.pending.pop(key, None)
            if p is None:
                continue  # already replayed or failed over
            view = (shard.arena.view(p.slab) if p.slab is not None
                    else None)
            try:
                res = _decode_payload(payload, view)
            except Exception as e:                          # noqa: BLE001
                res = QueryError(op=str(getattr(p.req, "op", "?")),
                                 error=type(e).__name__,
                                 message=f"payload decode failed: {e}")
            if p.slab is not None:
                shard.free_slabs.append(p.slab)
            if payload[0] == "slab":
                slab_n += 1
            else:
                inline_n += 1
            self._lat.append(now - p.t0)
            resolved.append((p.future, res))
        with self._stats_lock:
            self._stats["completed"] += len(resolved)
            self._stats["slab_payloads"] += slab_n
            self._stats["inline_payloads"] += inline_n
        return resolved

    def _handle_msg(self, shard: _Shard, msg) -> None:
        with shard.lock:
            resolved = self._handle_msg_locked(shard, msg)
        for fut, res in resolved:
            if not fut.done():
                fut.set_result(res)

    def _failover_target(self, dead_idx: int, p: _Pending) -> int | None:
        """Where a dead shard's in-flight request should go *now*:
        the healthiest other owner (any shard can answer — every worker
        holds the full database — but owners have the plane warm), or
        any live shard as a last resort; ``None`` keeps it on the
        respawning ring position."""
        if p.scatter:
            # any live shard can compute the original member's slice of
            # the (member, live) assignment — the mask is a pure function
            # of the ring, so the merge stays byte-identical
            cands = [s.index for s in self._shards
                     if s.index != dead_idx and s.health.rank() < 2]
        else:
            owners = self._owners_of(p.req)
            cands = [o for o in owners if o != dead_idx
                     and self._shards[o].health.rank() < 2]
            if not cands:
                cands = [s.index for s in self._shards
                         if s.index != dead_idx and s.health.rank() < 3]
        if not cands:
            return None
        return min(cands, key=lambda s: (self._shards[s].health.rank(),
                                         len(self._shards[s].pending)))

    def _redispatch(self, target_idx: int, pendings: list[_Pending]) -> None:
        """Failover: move in-flight pendings (same futures) onto a live
        shard's queue."""
        shard = self._shards[target_idx]
        rec = recorder()
        now = monotime()
        with shard.lock:
            if self._closed:
                return
            items = []
            for p in pendings:
                key = next(self._seq)
                p.slab = (shard.free_slabs.pop()
                          if shard.free_slabs and shard.slab_ok
                          and _slab_eligible(p.req, p.scatter) else None)
                p.t0 = now
                shard.pending[key] = p
                items.append((key, p.req, p.slab, p.scatter))
                if rec.enabled:
                    # zero-duration marker: this request crossed a worker
                    # death and failed over to a live replica
                    rec.record("failover", str(getattr(p.req, "op", "?")),
                               now, 0.0,
                               trace_id=getattr(p.req, "trace_id", None)
                               or "",
                               attrs={"to": target_idx,
                                      "replays": p.replays})
            self._send_locked(shard, items)
        with self._stats_lock:
            # a failover is still a replay (re-sent after loss) — the
            # failovers counter tracks the cross-replica subset
            self._stats["failovers"] += len(pendings)
            self._stats["replayed"] += len(pendings)

    def _handle_death(self, shard: _Shard) -> None:
        """The supervisor path: drain, fail over, back off, respawn,
        replay.

        The dead worker's link stays installed until the replacement is
        (both swaps happen under ``shard.lock``), so a concurrent
        :meth:`_dispatch` never touches a closed transport — at worst
        its message lands in the orphaned link and its pending entries
        are picked up by the recovery snapshot below.

        With replicas, in-flight requests that have another live owner
        are **failed over immediately** — re-dispatched to that owner
        before the respawn backoff, so a killed worker costs one
        failover hop, not a respawn wait.  During an epoch switch
        failover is suppressed (replays stay on this ring position) so
        sibling scatter partials can never straddle epochs.
        """
        resolved: list[tuple[Future, object]] = []
        with shard.lock:
            if self._closed or shard.proc is None or shard.proc.is_alive():
                return
            # responses the worker got out before dying still count
            peer = shard.peer
            while peer is not None:
                try:
                    msg = peer.recv(timeout=0.0, bypass_chaos=True)
                except PeerError:
                    break
                resolved.extend(self._handle_msg_locked(shard, msg))
            shard.proc.join(timeout=1.0)
            shard.deaths += 1
            deaths = shard.deaths
            shard.health.dead()
            # snapshot survivors now: failover must not wait out the
            # respawn backoff below
            survivors = sorted(shard.pending.items())  # dispatch order
            shard.pending.clear()
            replay: list[_Pending] = []
            doomed: list[_Pending] = []
            for _, p in survivors:
                if p.slab is not None:  # slab content is garbage now
                    shard.free_slabs.append(p.slab)
                    p.slab = None
                p.replays += 1
                (doomed if p.replays > self.replay_limit else replay).append(p)
        for fut, res in resolved:
            if not fut.done():
                fut.set_result(res)
        # freeze the recent span history: the last moments before this
        # death are exactly what a postmortem needs
        recorder().dump(f"worker_death shard={shard.index} deaths={deaths}")
        # cross-replica failover first (never during an epoch switch:
        # the target may already serve the new epoch)
        requeue: list[_Pending] = []
        by_target: dict[int, list[_Pending]] = {}
        if self._reopening or self.n_shards == 1:
            requeue = replay
        else:
            for p in replay:
                t = self._failover_target(shard.index, p)
                if t is None:
                    requeue.append(p)
                else:
                    by_target.setdefault(t, []).append(p)
            for t, ps in by_target.items():
                self._redispatch(t, ps)
        # exponential backoff so a worker that dies deterministically at
        # startup (corrupt database, OOM loop) cannot pin a CPU with a
        # fork-per-100ms respawn storm; requests arriving meanwhile queue
        # against the admission bound and are replayed below
        time.sleep(min(0.05 * (2 ** min(deaths - 1, 6)), 2.0))
        with shard.lock:
            if self._closed:
                return
            old_peer = shard.peer
            # dispatches that raced the failover snapshot above landed
            # in the dead worker's orphaned link: move them onto the
            # replacement with the same-position replays (they never
            # reached a worker, so their replay budget is untouched)
            for _, p in sorted(shard.pending.items()):
                if p.slab is not None:
                    shard.free_slabs.append(p.slab)
                    p.slab = None
                requeue.append(p)
            shard.pending.clear()
            self._spawn_locked(shard)
            shard.health.rejoining()
            if old_peer is not None and old_peer is not shard.peer:
                old_peer.close()
            items = []
            rec = recorder()
            now = monotime()
            for p in requeue:
                key = next(self._seq)
                p.slab = (shard.free_slabs.pop()
                          if shard.free_slabs and shard.slab_ok
                          and _slab_eligible(p.req, p.scatter) else None)
                p.t0 = now
                shard.pending[key] = p
                items.append((key, p.req, p.slab, p.scatter))
                if rec.enabled:
                    # zero-duration marker: this request crossed a worker
                    # death and was re-dispatched (its trace shows a
                    # second decode on the replacement worker)
                    rec.record("replay", str(getattr(p.req, "op", "?")),
                               now, 0.0,
                               trace_id=getattr(p.req, "trace_id", None)
                               or "",
                               attrs={"shard": shard.index,
                                      "replays": p.replays})
            if items:
                self._send_locked(shard, items)
            if self._reopening and self._reopen_dir is not None:
                # an epoch switch is in flight and the dead worker may
                # have swallowed — or already acked — its reopen message;
                # the replacement just came up on the pre-switch
                # directory, so re-send here or the switch wedges (the
                # ack loop's deaths check misses deaths that land before
                # it snapshots, and a send into the orphaned link is
                # silently lost).  Replays were queued first, so they
                # answer from the old epoch — the documented limit.
                self._send_locked(shard, ("reopen", self._reopen_dir))
        with self._stats_lock:
            self._stats["respawns"] += 1
            self._stats["replayed"] += len(requeue)
            self._stats["worker_lost"] += len(doomed)
        for p in doomed:
            if not p.future.done():
                p.future.set_result(QueryError(
                    op=str(getattr(p.req, "op", "?")), error="WorkerLost",
                    message=f"request killed its worker "
                            f"{p.replays - 1} time(s); giving up after "
                            f"{self.replay_limit} replays"))

    # -- observability --------------------------------------------------------
    def warm_reports(self) -> list[dict | None]:
        return [s.warm for s in self._shards]

    def metrics(self) -> dict:
        with self._stats_lock:
            out = dict(self._stats)
        out["n_shards"] = self.n_shards
        out["replicas"] = self.replicas
        out["transport"] = self.transport
        out["hedge_ms"] = self.hedge_ms
        out["slab_bytes"] = self.slab_bytes
        per = []
        for s in self._shards:
            with s.lock:
                entry = {"shard": s.index,
                         "pid": s.proc.pid if s.proc is not None else None,
                         "alive": bool(s.proc is not None
                                       and s.proc.is_alive()),
                         "pending": len(s.pending),
                         "deaths": s.deaths,
                         "free_slabs": len(s.free_slabs),
                         "health": s.health.snapshot(),
                         "warm": s.warm}
                chaos = s.chaos.active()
                if any(chaos[k] for k in ("drop", "delay_s", "stall")):
                    entry["chaos"] = chaos
                per.append(entry)
        out["shards"] = per
        return out
