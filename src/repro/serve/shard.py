"""Sharded multi-process query service: escape the GIL for decode-heavy load.

The in-process :class:`~repro.serve.engine.QueryServer` serves every plane
decode inside one Python process; past a few concurrent decode-heavy
clients the GIL is the ceiling (the ROADMAP limiter this module removes).
:class:`ShardedQueryServer` spawns ``n_shards`` worker *processes*, each
owning a full :class:`repro.query.Database` handle (its own mmap + decoded
-plane LRU), and routes every request with a consistent-hash ring keyed by
:meth:`QueryServer._locality_key` — so each plane is decoded and cached by
exactly one worker, and the per-worker LRU only ever holds planes the
router can send it.

Topology::

    clients -> BatchScheduler (per-shard admission queues)
                 |  serve_window(reqs): one batch message per shard
                 v
             ShardedQueryServer (parent)
               ring: locality_key -> shard          supervisor: respawn +
               payloads: shm slab arena per shard   replay on worker death
                 |             |             |
               worker 0      worker 1      worker N-1   (processes)
               Database      Database      Database
               own LRU       own LRU       own LRU

* **routing** — ``profile``/``window`` requests hash on ``(0, pid)``,
  ``stripe``/``value`` on ``(1, ctx)``; the ring is stable under shard-count
  changes (only ~1/N of keys move, and every moved key moves to the *new*
  shard — the classic consistent-hashing property, property-tested in
  ``tests/test_shard.py``).
* **scatter–gather** — summary-space queries (``topk``, ``threshold``)
  fan out to every shard restricted to the contexts it owns
  (``within=`` on the select functions) and the parent merges partials in
  the same deterministic ``(-value, ctx)`` order, so results are identical
  to single-process serving.
* **payloads** — plane-sized results return through a parent-owned
  :class:`~repro.runtime.shm.SlabArena` (the PR 3 slab transport): the
  worker serializes straight into the slab and ships a tiny descriptor;
  only results that outgrow their slab fall back to pickling through the
  response queue.  Workers never *create* segments, so a SIGKILL'd worker
  cannot leak ``/dev/shm``.
* **fault tolerance** — a per-shard pump thread doubles as supervisor:
  when a worker dies it drains the responses that did arrive, respawns the
  worker (same ring position, fresh Database), and replays every
  unanswered in-flight request to the replacement — a killed worker costs
  latency, never wrong answers.  A request that outlives ``replay_limit``
  respawns (it is probably what keeps killing workers) resolves to a
  structured ``QueryError("WorkerLost")`` instead of looping forever.
"""
from __future__ import annotations

import hashlib
import itertools
import multiprocessing as mp
import os
import queue as queue_mod
import sys
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FutureTimeout
from dataclasses import dataclass, field

import numpy as np

from repro.core.sparse import SparseMetrics, Trace
from repro.obs import MetricsRegistry, configure, monotime, recorder
from repro.runtime.shm import (SlabArena, read_section, sections_layout,
                               worker_slab, write_section)
from repro.serve.engine import QueryError, QueryRequest, QueryServer

#: summary-space ops served by every shard over its owned contexts and
#: merged in the parent (all other ops route to exactly one shard)
SCATTER_OPS = ("topk", "threshold")

#: worker replies per response-queue message (latency/throughput balance)
_REPLY_CHUNK = 16

#: ops whose results are plane/array-sized and worth a shm slab; the rest
#: (point values, top-k rows, errors) ride the pickled response queue and
#: must not starve the slab pool
_SLAB_OPS = ("profile", "stripe", "window", "threshold")


def _slab_eligible(req: QueryRequest, scatter: bool) -> bool:
    return not scatter and getattr(req, "op", None) in _SLAB_OPS


# ---------------------------------------------------------------------------
# epoch transitions: many dispatch windows XOR one reopen
# ---------------------------------------------------------------------------

class _RWLock:
    """Reader/writer lock with writer preference.

    Dispatch windows are readers (arbitrarily many in flight); an epoch
    :meth:`ShardedQueryServer.reopen` is the writer.  Writer preference —
    a waiting reopen blocks *new* windows — so a steady query stream can
    never starve an epoch switch, and every window that does run is
    entirely before or entirely after the switch: no batched reply ever
    mixes epochs.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if not self._readers:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


# ---------------------------------------------------------------------------
# consistent-hash ring
# ---------------------------------------------------------------------------

def _hash64(data: bytes) -> int:
    """Stable 64-bit point on the ring (blake2b: no PYTHONHASHSEED drift,
    identical in parent and every worker)."""
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(),
                          "little")


class ConsistentHashRing:
    """Classic vnode hash ring over locality keys.

    Each shard owns ``vnodes`` pseudo-random points; a key routes to the
    first point clockwise from its own hash.  Growing the ring from N to
    N+1 shards only adds points, so the *only* keys that change owner are
    the ones the new shard's points capture — an expected 1/(N+1) of the
    key space, and every moved key moves to the new shard.
    """

    def __init__(self, n_shards: int, *, vnodes: int = 96,
                 salt: bytes = b"repro-serve-shard"):
        self.n_shards = max(1, int(n_shards))
        self.vnodes = max(1, int(vnodes))
        self.salt = bytes(salt)
        pts = sorted(
            (_hash64(b"%s|vnode|%d:%d" % (self.salt, s, v)), s)
            for s in range(self.n_shards) for v in range(self.vnodes))
        self._points = np.array([h for h, _ in pts], dtype=np.uint64)
        self._owner = np.array([s for _, s in pts], dtype=np.int64)

    def route_key(self, key: tuple[int, int]) -> int:
        """Locality key ``(group, id)`` -> owning shard."""
        h = _hash64(b"%s|key|%d:%d" % (self.salt, int(key[0]), int(key[1])))
        i = int(np.searchsorted(self._points, np.uint64(h), side="left"))
        return int(self._owner[i % self._points.size])

    def route(self, req: QueryRequest) -> int:
        return self.route_key(QueryServer._locality_key(req))

    def owned_contexts(self, n_contexts: int, shard: int) -> np.ndarray:
        """Context ids whose ``(1, ctx)`` key routes to ``shard`` — the
        ``within=`` set for scatter queries and CMS warm ownership."""
        return np.array([c for c in range(int(n_contexts))
                         if self.route_key((1, c)) == int(shard)],
                        dtype=np.int64)

    def owned_context_mask(self, n_contexts: int, shard: int) -> np.ndarray:
        """Boolean ownership over context ids — the O(1)-lookup ``within=``
        form the worker hands to the select functions per scatter query."""
        mask = np.zeros(int(n_contexts), dtype=bool)
        mask[self.owned_contexts(n_contexts, shard)] = True
        return mask

    def owns_plane(self, store: str, oid: int, shard: int) -> bool:
        """Warm-plan ownership: PMS/trace planes follow the profile key,
        CMS planes the context key."""
        group = 1 if store == "cms" else 0
        return self.route_key((group, int(oid))) == int(shard)


# ---------------------------------------------------------------------------
# result payload codec (worker -> parent)
# ---------------------------------------------------------------------------
# payload = (mode, kind, data):
#   ("obj",    None,    result)  - small results (floats, topk rows, errors)
#                                  pickled through the response queue
#   ("slab",   "sm",    nbytes)  - SparseMetrics.encode_into the slab
#   ("inline", "sm",    bytes)   - ... that outgrew the slab
#   ("slab",   kind,    meta)    - array sections in the slab; meta is
#                                  ((dtype, count, nbytes), ...) and offsets
#                                  re-derive via sections_layout
#   ("inline", kind,    arrays)  - ... that outgrew the slab
# kind "pair" reassembles a (profiles, values)-style tuple, "trace" a Trace.

def _encode_result(res, slab_buf, slab_bytes: int):
    """Serialize one query result, preferring the shard's shm slab."""
    if isinstance(res, SparseMetrics):
        n = res.encoded_nbytes()
        if slab_buf is not None and n <= slab_bytes:
            res.encode_into(slab_buf, 0)
            return ("slab", "sm", n)
        return ("inline", "sm", res.encode())
    if isinstance(res, Trace):
        kind, arrays = "trace", (res.time, res.ctx)
    elif (isinstance(res, tuple) and len(res) == 2
          and all(isinstance(a, np.ndarray) for a in res)):
        kind, arrays = "pair", res
    else:
        return ("obj", None, res)
    arrays = tuple(np.ascontiguousarray(a) for a in arrays)
    meta = tuple((a.dtype.str, int(a.size), int(a.nbytes)) for a in arrays)
    offs, total = sections_layout([m[2] for m in meta])
    if slab_buf is not None and total <= slab_bytes:
        for a, off in zip(arrays, offs):
            write_section(slab_buf, off, a)
        return ("slab", kind, meta)
    return ("inline", kind, arrays)


def _decode_payload(payload, slab_view):
    """Parent-side inverse of :func:`_encode_result`; always copies out of
    the slab so it can be recycled immediately."""
    mode, kind, data = payload
    if mode == "obj":
        return data
    if kind == "sm":
        buf = bytes(slab_view[:data]) if mode == "slab" else data
        return SparseMetrics.decode(buf)[0]
    if mode == "inline":
        arrays = tuple(data)
    else:
        offs, _ = sections_layout([nb for _, _, nb in data])
        arrays = tuple(read_section(slab_view, off, dt, n, copy=True)
                       for (dt, n, _), off in zip(data, offs))
    return Trace(*arrays) if kind == "trace" else arrays


# ---------------------------------------------------------------------------
# worker process
# ---------------------------------------------------------------------------

def _serve_scatter(db, owned_ctx: np.ndarray, req: QueryRequest):
    """One shard's partial answer to a scatter query, restricted to the
    contexts it owns; failures mirror ``QueryServer.serve_one`` exactly so
    error results stay byte-identical to single-process serving."""
    from repro.query import threshold_contexts, topk_hot_paths
    try:
        params = dict(req.params)
        if req.op == "topk":
            return topk_hot_paths(db, req.metric, k=req.k,
                                  inclusive=req.inclusive, within=owned_ctx,
                                  **params)
        return threshold_contexts(
            db, req.metric, min_value=float(params.pop("min_value", 0.0)),
            inclusive=req.inclusive, within=owned_ctx, **params)
    except Exception as e:                                  # noqa: BLE001
        return QueryError(op=str(getattr(req, "op", "?")),
                          error=type(e).__name__, message=str(e))


def _merge_scatter(req: QueryRequest, parts: list):
    """Parent-side merge of per-shard partials, in the exact deterministic
    order the single-process select functions use."""
    for p in parts:
        if isinstance(p, QueryError):
            return p
    if req.op == "topk":
        rows = [h for part in parts for h in part]
        rows.sort(key=lambda h: (-h.value, h.ctx))
        return rows[:max(int(req.k), 0)]
    ctx = np.concatenate([p[0] for p in parts])
    vals = np.concatenate([p[1] for p in parts])
    order = np.lexsort((ctx, -vals))  # value desc, ctx asc tiebreak
    return ctx[order], vals[order]


def _shard_worker_main(shard: int, n_shards: int, vnodes: int, salt: bytes,
                       db_dir: str, cache_bytes: int, warm_bytes,
                       server_factory, slab_bytes: int, trace_ring: int,
                       req_q, resp_q):
    """Worker loop: own Database, own LRU, serve batches in locality order.

    Module-level (and all-args-picklable) so it runs under any
    multiprocessing start method.  The worker never creates shm segments —
    oversize results fall back to the pickled response queue — so abrupt
    death cannot leak ``/dev/shm``.

    The worker runs its own flight recorder (sized by ``trace_ring`` —
    passed explicitly so spawn-start workers match the parent's config)
    and piggybacks freshly recorded spans on every reply chunk, so span
    shipping costs no extra queue round trips and a SIGKILL loses at
    most the spans of the unanswered batch (which the parent's replay
    re-records on the replacement worker anyway).
    """
    import signal

    from repro.query import Database
    from repro.serve.warm import warm_cache

    signal.signal(signal.SIGINT, signal.SIG_IGN)  # parent owns shutdown
    rec = configure(trace_ring)
    rec.default_shard = shard
    ring = ConsistentHashRing(n_shards, vnodes=vnodes, salt=salt)
    owned = ((lambda store, oid: ring.owns_plane(store, oid, shard))
             if n_shards > 1 else None)

    def _open(path):
        d = Database(path, cache_bytes=cache_bytes)
        srv = (server_factory or QueryServer)(d)
        octx = (ring.owned_context_mask(d.n_contexts, shard)
                if n_shards > 1 else None)
        report = None
        if warm_bytes is None or warm_bytes > 0:
            report = warm_cache(d, warm_bytes, owned=owned)
        return d, srv, octx, report

    db, server, owned_ctx, warm_report = _open(db_dir)
    resp_q.put(("ready", {"shard": shard, "pid": os.getpid(),
                          "warm": warm_report}))
    while True:
        msg = req_q.get()
        if msg is None:
            break
        if isinstance(msg, tuple) and msg and msg[0] == "reopen":
            # epoch switch: messages are processed serially, so every
            # batch queued before this one was answered from the old
            # epoch — closing here is safe because every result path
            # copies out of the mmap before replying.  A fresh Database
            # means a fresh (empty) plane LRU: cache invalidation is
            # structural, not key-by-key.
            new_dir = msg[1]
            db.close()
            db, server, owned_ctx, warm_report = _open(new_dir)
            resp_q.put(("reopened", {"shard": shard, "pid": os.getpid(),
                                     "dir": new_dir, "warm": warm_report}))
            continue
        items = msg  # [(key, QueryRequest, slab_name | None, scatter), ...]
        # plane-less ops (group 2: top-k/threshold partials) first — they
        # are barrier legs of scatter-gather merges, so answering them
        # early keeps sibling shards' merges from waiting out this
        # shard's plane work; then plane ops in locality order
        order = sorted(range(len(items)),
                       key=lambda i: (lambda k: (k[0] != 2, k))(
                           QueryServer._locality_key(items[i][1])))
        replies = []
        for i in order:  # every hot plane decodes once per batch
            key, req, slab_name, scatter = items[i]
            tid = getattr(req, "trace_id", None) or ""
            try:
                if scatter and req.op in SCATTER_OPS and owned_ctx is not None:
                    # scatter partials bypass serve_one (and its decode
                    # span), so time them here
                    t0 = monotime()
                    res = _serve_scatter(db, owned_ctx, req)
                    if rec.enabled:
                        rec.record("decode", str(req.op), t0, monotime() - t0,
                                   trace_id=tid)
                else:
                    res = server.serve_one(req)
                slab_buf = (worker_slab(slab_name).buf
                            if slab_name is not None else None)
                t0 = monotime()
                payload = _encode_result(res, slab_buf, slab_bytes)
                if rec.enabled:
                    rec.record("encode", str(getattr(req, "op", "?")), t0,
                               monotime() - t0, trace_id=tid)
            except Exception as e:                          # noqa: BLE001
                payload = ("obj", None, QueryError(
                    op=str(getattr(req, "op", "?")),
                    error=type(e).__name__, message=str(e)))
            replies.append((key, payload))
            # chunked responses: the mp.Queue round trip amortizes over
            # a chunk instead of being paid per request, while early
            # results still stream back before the batch finishes (a
            # whole-batch reply would stall closed-loop clients and
            # drain the pipeline).  Spans recorded since the last chunk
            # ride the same message.
            if len(replies) >= _REPLY_CHUNK:
                resp_q.put(("res", replies, rec.drain_outbox()))
                replies = []
        tail = rec.drain_outbox()
        if replies or tail:
            resp_q.put(("res", replies, tail))
    db.close()


# ---------------------------------------------------------------------------
# parent: shard records, supervisor, scatter-gather
# ---------------------------------------------------------------------------

@dataclass
class _Pending:
    req: QueryRequest
    future: Future
    slab: str | None
    scatter: bool
    replays: int = 0


@dataclass
class _Shard:
    index: int
    arena: SlabArena
    free_slabs: list[str]
    lock: threading.Lock = field(default_factory=threading.Lock)
    pending: dict[int, _Pending] = field(default_factory=dict)
    proc: mp.process.BaseProcess | None = None
    req_q: object = None
    resp_q: object = None
    ready: threading.Event = field(default_factory=threading.Event)
    reopen_ack: threading.Event = field(default_factory=threading.Event)
    warm: dict | None = None
    deaths: int = 0


class ShardedQueryServer:
    """Multi-process drop-in for :class:`QueryServer` over one database.

    Exposes the same serving surface the scheduler and HTTP layer consume
    (``serve_one`` / ``serve`` / ``_locality_key``) plus the shard-aware
    hooks the :class:`~repro.serve.scheduler.BatchScheduler` uses when
    present (``n_shards``, ``shard_of``, ``serve_window``).

    ``cache_bytes``/``warm_bytes`` are *per worker*: sharding scales cache
    capacity with compute, and the router guarantees the budgets never
    hold overlapping planes.
    """

    def __init__(self, db_dir: str, n_shards: int, *,
                 cache_bytes: int = 64 << 20, warm_bytes: int | None = 0,
                 n_slabs: int = 32, slab_bytes: int = 4 << 20,
                 vnodes: int = 96, server_factory=None,
                 replay_limit: int = 3, dispatch_timeout_s: float = 60.0,
                 start_timeout_s: float = 120.0, mp_context: str | None = None,
                 trace_ring: int | None = None):
        if db_dir is None:
            raise ValueError("sharded serving needs a database directory "
                             "(explicit pms_path handles cannot be re-opened "
                             "by workers)")
        self.db_dir = str(db_dir)
        self.n_shards = max(1, int(n_shards))
        self.cache_bytes = int(cache_bytes)
        self.warm_bytes = warm_bytes
        self.n_slabs = max(1, int(n_slabs))
        self.slab_bytes = max(1 << 12, int(slab_bytes))
        self.ring = ConsistentHashRing(self.n_shards, vnodes=vnodes)
        self.server_factory = server_factory
        self.replay_limit = int(replay_limit)
        self.dispatch_timeout_s = float(dispatch_timeout_s)
        self.start_timeout_s = float(start_timeout_s)

        # value lookups are served from a CMS stripe when that store
        # exists, so they route context-major like stripes; a PMS-only
        # database answers them from the *profile* plane instead — route
        # them profile-major there, or every shard would decode (and
        # warm) the same PMS planes the ring assigned to one owner
        from repro.query.database import CMS_NAME
        self._has_cms = os.path.exists(os.path.join(self.db_dir, CMS_NAME))

        if mp_context is None:
            mp_context = os.environ.get("REPRO_MP_CONTEXT") or None
        if mp_context is None:
            # same tradeoff as runtime.processes: fork on Linux (spawn
            # re-imports __main__), REPRO_MP_CONTEXT=forkserver opts out
            methods = mp.get_all_start_methods()
            mp_context = ("fork" if sys.platform == "linux"
                          and "fork" in methods else "spawn")
        self._ctx = mp.get_context(mp_context)

        # flight-recorder ring size for the worker processes; None
        # inherits this (parent) process's configured capacity, so one
        # `configure()` at the front covers the fleet under any mp start
        # method (spawn workers don't inherit parent globals)
        self.trace_ring = (recorder().capacity if trace_ring is None
                           else max(0, int(trace_ring)))

        self._shards: list[_Shard] = []
        self._pumps: list[threading.Thread] = []
        self._seq = itertools.count()
        self._started = False
        self._closed = False
        self._stats_lock = threading.Lock()
        self.obs = MetricsRegistry()
        self._stats = self.obs.group(
            "shard", {"dispatched": 0, "completed": 0, "respawns": 0,
                      "worker_lost": 0, "replayed": 0, "scatter_queries": 0,
                      "deduped": 0, "slab_payloads": 0,
                      "inline_payloads": 0, "reopens": 0,
                      "reopen_last_s": 0.0},
            gauges=("reopen_last_s",))
        self._rw = _RWLock()  # windows are readers, reopen() the writer

    # make the scheduler's locality sort work unchanged
    _locality_key = staticmethod(QueryServer._locality_key)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ShardedQueryServer":
        if self._started:
            return self
        self._started = True
        try:
            for s in range(self.n_shards):
                arena = SlabArena(self.n_slabs, self.slab_bytes)
                shard = _Shard(index=s, arena=arena,
                               free_slabs=list(arena._free))
                self._shards.append(shard)
                self._spawn_locked(shard)
            for shard in self._shards:
                pump = threading.Thread(target=self._pump_loop,
                                        args=(shard.index,), daemon=True,
                                        name=f"shard-pump-{shard.index}")
                pump.start()
                self._pumps.append(pump)
            deadline = monotime() + self.start_timeout_s
            for shard in self._shards:
                # re-read shard.ready each poll: a worker that crashes
                # during startup is respawned by the supervisor with a
                # FRESH Event, and waiting on the original object would
                # miss the replacement's ready signal
                while not shard.ready.wait(0.1):
                    if monotime() > deadline:
                        raise RuntimeError(
                            f"shard {shard.index} worker failed to become "
                            f"ready within {self.start_timeout_s:.0f}s")
        except BaseException:
            self.close()
            raise
        return self

    def _spawn_locked(self, shard: _Shard) -> None:
        """(Re)create one worker; caller holds ``shard.lock`` on respawn."""
        shard.req_q = self._ctx.Queue()
        shard.resp_q = self._ctx.Queue()
        shard.ready = threading.Event()
        shard.proc = self._ctx.Process(
            target=_shard_worker_main,
            args=(shard.index, self.n_shards, self.ring.vnodes,
                  self.ring.salt, self.db_dir, self.cache_bytes,
                  self.warm_bytes, self.server_factory, self.slab_bytes,
                  self.trace_ring, shard.req_q, shard.resp_q),
            daemon=True, name=f"repro-shard-{shard.index}")
        shard.proc.start()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for shard in self._shards:
            with shard.lock:
                if shard.req_q is not None:
                    try:
                        shard.req_q.put(None)
                    except Exception:
                        pass
        for pump in self._pumps:
            pump.join(timeout=10.0)
        leftovers: list[_Pending] = []
        for shard in self._shards:
            with shard.lock:
                leftovers.extend(shard.pending.values())
                shard.pending.clear()
            if shard.proc is not None:
                shard.proc.join(timeout=5.0)
                if shard.proc.is_alive():
                    shard.proc.terminate()
                    shard.proc.join(timeout=2.0)
                if shard.proc.is_alive():
                    shard.proc.kill()
                    shard.proc.join(timeout=2.0)
            for q in (shard.req_q, shard.resp_q):
                if q is not None:
                    try:
                        q.close()
                        q.cancel_join_thread()
                    except Exception:
                        pass
            shard.arena.close()
        for p in leftovers:
            if not p.future.done():
                try:
                    p.future.set_exception(
                        RuntimeError("sharded query server closed"))
                except Exception:
                    pass

    def __enter__(self) -> "ShardedQueryServer":
        return self.start()

    def __exit__(self, *a) -> None:
        self.close()

    # -- epoch transitions ----------------------------------------------------
    def reopen(self, db_dir: str) -> dict:
        """Move every worker to a new database directory without restart.

        Takes the window lock exclusively (writer preference — a query
        stream cannot starve the switch), sends each worker a ``reopen``
        control message, and waits for all acks.  Worker queues are FIFO
        and processed serially, so every batch dispatched before this
        call is answered from the *old* epoch and every batch after it
        from the new one — the window lock makes that boundary cover
        whole dispatch windows, so no batched reply mixes epochs.

        A worker that dies mid-switch is respawned by the supervisor on
        the previous directory (replays land on the old epoch — the
        documented recovery limit) and the reopen message is re-sent, so
        the switch still converges.
        """
        if not self._started:
            raise RuntimeError("sharded query server is not started")
        if self._closed:
            raise RuntimeError("sharded query server is closed")
        from repro.query.database import CMS_NAME
        new_dir = str(db_dir)
        t0 = monotime()
        self._rw.acquire_write()
        try:
            for shard in self._shards:
                with shard.lock:
                    shard.reopen_ack = threading.Event()
                    shard.req_q.put(("reopen", new_dir))
            deadline = monotime() + self.start_timeout_s
            for shard in self._shards:
                seen = shard.deaths
                while not shard.reopen_ack.wait(0.1):
                    if self._closed:
                        raise RuntimeError("sharded query server closed "
                                           "during reopen")
                    with shard.lock:
                        if shard.deaths != seen:
                            # the worker died mid-switch; its replacement
                            # came up on the old directory — re-send
                            seen = shard.deaths
                            shard.req_q.put(("reopen", new_dir))
                    if monotime() > deadline:
                        raise RuntimeError(
                            f"shard {shard.index} did not ack reopen "
                            f"within {self.start_timeout_s:.0f}s")
            # respawns-after-death from here on land on the new epoch
            self.db_dir = new_dir
            self._has_cms = os.path.exists(os.path.join(new_dir, CMS_NAME))
            dt = monotime() - t0
            with self._stats_lock:
                self._stats["reopens"] += 1
                self._stats["reopen_last_s"] = dt
            return {"dir": new_dir, "seconds": dt}
        finally:
            self._rw.release_write()

    # -- routing -------------------------------------------------------------
    def shard_of(self, req: QueryRequest) -> int | None:
        """Owning shard for a request; ``None`` means scatter to all."""
        op = getattr(req, "op", None)
        if self.n_shards > 1 and op in SCATTER_OPS:
            return None
        if op == "value" and not self._has_cms:
            # PMS-only database: the plane a value lookup touches is the
            # profile plane, so route to its owner
            try:
                return self.ring.route_key((0, int(req.pid or 0)))
            except (TypeError, ValueError):
                pass
        return self.ring.route(req)

    def worker_pids(self) -> list[int]:
        return [s.proc.pid for s in self._shards if s.proc is not None]

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self, shard_idx: int,
                  reqs: list[tuple[QueryRequest, bool]]) -> list[Future]:
        """Send ``[(request, scatter), ...]`` to one worker as a single
        batch message; returns one Future per entry."""
        shard = self._shards[shard_idx]
        items, futs = [], []
        with shard.lock:
            if self._closed:
                raise RuntimeError("sharded query server is closed")
            for req, scatter in reqs:
                key = next(self._seq)
                slab = (shard.free_slabs.pop()
                        if shard.free_slabs and _slab_eligible(req, scatter)
                        else None)
                p = _Pending(req, Future(), slab, scatter)
                shard.pending[key] = p
                items.append((key, req, slab, scatter))
                futs.append(p.future)
            shard.req_q.put(items)
        with self._stats_lock:
            self._stats["dispatched"] += len(items)
        return futs

    def _await(self, fut: Future, req: QueryRequest):
        try:
            return fut.result(timeout=self.dispatch_timeout_s)
        except FutureTimeout:
            return QueryError(op=str(getattr(req, "op", "?")),
                              error="ShardTimeout",
                              message=f"no shard response within "
                                      f"{self.dispatch_timeout_s:.0f}s")
        except Exception as e:                              # noqa: BLE001
            return QueryError(op=str(getattr(req, "op", "?")),
                              error=type(e).__name__, message=str(e))

    # -- serving surface ------------------------------------------------------
    @staticmethod
    def _dedupe_key(req: QueryRequest):
        """Hashable identity of a request, or None if it has one-off
        unhashable params (then it just doesn't coalesce)."""
        try:
            key = (req.op, req.pid, req.ctx, req.metric, req.inclusive,
                   req.k, req.t0, req.t1,
                   tuple(sorted(req.params.items())))
            hash(key)  # params values may be unhashable (JSON lists)
            return key
        except TypeError:
            return None

    @staticmethod
    def _merged_future(req: QueryRequest, parts: list[Future]) -> Future:
        """A Future that resolves to the scatter-gather merge once every
        per-shard partial has resolved (merge runs on the last pump
        thread to deliver — never blocks a caller)."""
        merged: Future = Future()
        remaining = [len(parts)]
        lock = threading.Lock()

        def on_done(_f: Future) -> None:
            with lock:
                remaining[0] -= 1
                if remaining[0]:
                    return
            t0 = monotime()
            try:
                vals = []
                for f in parts:
                    exc = f.exception()
                    if exc is not None:
                        vals.append(QueryError(
                            op=str(getattr(req, "op", "?")),
                            error=type(exc).__name__, message=str(exc)))
                    else:
                        vals.append(f.result())
                res = _merge_scatter(req, vals)
            except Exception as e:                          # noqa: BLE001
                res = QueryError(op=str(getattr(req, "op", "?")),
                                 error=type(e).__name__, message=str(e))
            rec = recorder()
            if rec.enabled:
                rec.record("merge", str(getattr(req, "op", "?")), t0,
                           monotime() - t0,
                           trace_id=getattr(req, "trace_id", None) or "",
                           attrs={"parts": len(parts)})
            if not merged.done():
                merged.set_result(res)

        for f in parts:
            f.add_done_callback(on_done)
        return merged

    def serve_window_async(self, reqs: list[QueryRequest]) -> list[Future]:
        """Dispatch a batch and return one Future per request slot.

        One message per shard per window (the worker re-sorts its slice
        in plane-locality order and streams replies back in chunks);
        scatter ops ride along in every shard's message and resolve
        through a merge future.  Identical requests in a window are
        *coalesced* before dispatch — the cross-process analog of "the
        cache does the batching": a burst of clients asking for the same
        hot plane costs one worker response (and one shm payload), and
        every duplicate slot shares the same Future, exactly like LRU
        hits share a decoded plane in-process.
        """
        if not self._started:
            raise RuntimeError("sharded query server is not started")
        self._rw.acquire_read()
        try:
            return self._serve_window_async_locked(reqs)
        finally:
            self._rw.release_read()

    def _serve_window_async_locked(self,
                                   reqs: list[QueryRequest]) -> list[Future]:
        alias = list(range(len(reqs)))
        reps: dict[object, int] = {}
        for i, req in enumerate(reqs):
            k = self._dedupe_key(req)
            if k is not None:
                alias[i] = reps.setdefault(k, i)
        n_unique = len(set(alias))
        per_shard: list[list[tuple[int, QueryRequest, bool]]] = \
            [[] for _ in range(self.n_shards)]
        n_scatter = 0
        for i, req in enumerate(reqs):
            if alias[i] != i:
                continue  # a duplicate slot shares its representative
            s = self.shard_of(req)
            if s is None:
                n_scatter += 1
                for t in range(self.n_shards):
                    per_shard[t].append((i, req, True))
            else:
                per_shard[s].append((i, req, False))
        with self._stats_lock:
            self._stats["scatter_queries"] += n_scatter
            self._stats["deduped"] += len(reqs) - n_unique
        futs: list[Future | None] = [None] * len(reqs)
        scatter_parts: dict[int, list[Future]] = {}
        for s, items in enumerate(per_shard):
            if not items:
                continue
            for (i, req, scatter), fut in zip(
                    items, self._dispatch(s, [(r, sc)
                                              for _, r, sc in items])):
                if scatter:
                    scatter_parts.setdefault(i, []).append(fut)
                else:
                    futs[i] = fut
        for i, parts in scatter_parts.items():
            futs[i] = self._merged_future(reqs[i], parts)
        for i, j in enumerate(alias):
            if j != i:
                futs[i] = futs[j]
        return futs

    def serve_window(self, reqs: list[QueryRequest]) -> list:
        """Blocking :meth:`serve_window_async`: results in request order,
        failures as inline :class:`QueryError` values."""
        futs = self.serve_window_async(reqs)
        return [self._await(f, r) for f, r in zip(futs, reqs)]

    def serve(self, reqs: list[QueryRequest]) -> list:
        return self.serve_window(reqs)

    def serve_one(self, req: QueryRequest):
        return self.serve_window([req])[0]

    def submit(self, req: QueryRequest):
        """Single-request convenience mirroring ``QueryServer.submit``:
        raises structured failures instead of returning them."""
        res = self.serve_one(req)
        if isinstance(res, QueryError):
            raise RuntimeError(f"{res.error}: {res.message} (op={res.op})")
        return res

    # -- supervisor -----------------------------------------------------------
    def _pump_loop(self, shard_idx: int) -> None:
        shard = self._shards[shard_idx]
        while not self._closed:
            resp_q, proc = shard.resp_q, shard.proc
            try:
                msg = resp_q.get(timeout=0.1)
            except queue_mod.Empty:
                if proc is not None and not proc.is_alive() \
                        and not self._closed:
                    self._handle_death(shard)
                continue
            except (EOFError, OSError):
                if not self._closed:
                    self._handle_death(shard)
                continue
            self._handle_msg(shard, msg)

    def _handle_msg_locked(self, shard: _Shard, msg
                           ) -> list[tuple[Future, object]]:
        """Decode one worker message; caller holds ``shard.lock`` and
        resolves the returned futures *after* releasing it."""
        if msg[0] == "ready":
            shard.warm = msg[1]
            shard.ready.set()
            return []
        if msg[0] == "reopened":
            shard.warm = msg[1].get("warm")
            shard.reopen_ack.set()
            return []
        if len(msg) > 2 and msg[2]:
            # spans the worker piggybacked on this reply chunk
            recorder().extend(msg[2])
        resolved: list[tuple[Future, object]] = []
        slab_n = inline_n = 0
        for key, payload in msg[1]:
            p = shard.pending.pop(key, None)
            if p is None:
                continue  # already replayed or failed over
            view = (shard.arena.view(p.slab) if p.slab is not None
                    else None)
            try:
                res = _decode_payload(payload, view)
            except Exception as e:                          # noqa: BLE001
                res = QueryError(op=str(getattr(p.req, "op", "?")),
                                 error=type(e).__name__,
                                 message=f"payload decode failed: {e}")
            if p.slab is not None:
                shard.free_slabs.append(p.slab)
            if payload[0] == "slab":
                slab_n += 1
            else:
                inline_n += 1
            resolved.append((p.future, res))
        with self._stats_lock:
            self._stats["completed"] += len(resolved)
            self._stats["slab_payloads"] += slab_n
            self._stats["inline_payloads"] += inline_n
        return resolved

    def _handle_msg(self, shard: _Shard, msg) -> None:
        with shard.lock:
            resolved = self._handle_msg_locked(shard, msg)
        for fut, res in resolved:
            if not fut.done():
                fut.set_result(res)

    def _handle_death(self, shard: _Shard) -> None:
        """The supervisor path: drain, back off, respawn, replay.

        The dead worker's queues stay open until the replacement is
        installed (both swaps happen under ``shard.lock``), so a
        concurrent :meth:`_dispatch` never touches a closed queue — at
        worst its message lands in the orphaned queue and its pending
        entries are picked up by the replay snapshot below.
        """
        resolved: list[tuple[Future, object]] = []
        with shard.lock:
            if self._closed or shard.proc is None or shard.proc.is_alive():
                return
            # responses the worker got out before dying still count
            while True:
                try:
                    msg = shard.resp_q.get_nowait()
                except (queue_mod.Empty, EOFError, OSError):
                    break
                resolved.extend(self._handle_msg_locked(shard, msg))
            shard.proc.join(timeout=1.0)
            shard.deaths += 1
            deaths = shard.deaths
        for fut, res in resolved:
            if not fut.done():
                fut.set_result(res)
        # freeze the recent span history: the last moments before this
        # death are exactly what a postmortem needs
        recorder().dump(f"worker_death shard={shard.index} deaths={deaths}")
        # exponential backoff so a worker that dies deterministically at
        # startup (corrupt database, OOM loop) cannot pin a CPU with a
        # fork-per-100ms respawn storm; requests arriving meanwhile queue
        # against the admission bound and are replayed below
        time.sleep(min(0.05 * (2 ** min(deaths - 1, 6)), 2.0))
        doomed: list[_Pending] = []
        with shard.lock:
            if self._closed:
                return
            old_qs = (shard.req_q, shard.resp_q)
            survivors = sorted(shard.pending.items())  # dispatch order
            shard.pending.clear()
            replay: list[_Pending] = []
            for _, p in survivors:
                if p.slab is not None:  # slab content is garbage now
                    shard.free_slabs.append(p.slab)
                    p.slab = None
                p.replays += 1
                (doomed if p.replays > self.replay_limit else replay).append(p)
            self._spawn_locked(shard)
            for q in old_qs:
                try:
                    q.close()
                    q.cancel_join_thread()
                except Exception:
                    pass
            items = []
            rec = recorder()
            now = monotime()
            for p in replay:
                key = next(self._seq)
                p.slab = (shard.free_slabs.pop()
                          if shard.free_slabs
                          and _slab_eligible(p.req, p.scatter) else None)
                shard.pending[key] = p
                items.append((key, p.req, p.slab, p.scatter))
                if rec.enabled:
                    # zero-duration marker: this request crossed a worker
                    # death and was re-dispatched (its trace shows a
                    # second decode on the replacement worker)
                    rec.record("replay", str(getattr(p.req, "op", "?")),
                               now, 0.0,
                               trace_id=getattr(p.req, "trace_id", None)
                               or "",
                               attrs={"shard": shard.index,
                                      "replays": p.replays})
            if items:
                shard.req_q.put(items)
        with self._stats_lock:
            self._stats["respawns"] += 1
            self._stats["replayed"] += len(replay)
            self._stats["worker_lost"] += len(doomed)
        for p in doomed:
            if not p.future.done():
                p.future.set_result(QueryError(
                    op=str(getattr(p.req, "op", "?")), error="WorkerLost",
                    message=f"request killed its worker "
                            f"{p.replays - 1} time(s); giving up after "
                            f"{self.replay_limit} replays"))

    # -- observability --------------------------------------------------------
    def warm_reports(self) -> list[dict | None]:
        return [s.warm for s in self._shards]

    def metrics(self) -> dict:
        with self._stats_lock:
            out = dict(self._stats)
        out["n_shards"] = self.n_shards
        out["slab_bytes"] = self.slab_bytes
        per = []
        for s in self._shards:
            with s.lock:
                per.append({"shard": s.index,
                            "pid": s.proc.pid if s.proc is not None else None,
                            "alive": bool(s.proc is not None
                                          and s.proc.is_alive()),
                            "pending": len(s.pending),
                            "deaths": s.deaths,
                            "free_slabs": len(s.free_slabs),
                            "warm": s.warm})
        out["shards"] = per
        return out
