"""Startup cache warming: preload the hottest planes before traffic lands.

A cold query server pays one plane decode per first touch — exactly the
p99 spike an interactive browser notices.  The completed database already
knows where the heat is without reading a single plane: the summary
statistics section says how many values every context carries, the store
indexes say what each plane costs in bytes, and the trace table of
contents says how many samples each timeline segment holds.
:func:`warm_cache` turns that into a greedy knapsack over the
byte-budgeted LRU:

* a CMS context plane's *heat* is its total value population (the
  ``count`` summary stat summed over the context's metrics — i.e. how much
  of the database lives there, a direct proxy for stripe/point traffic);
* a PMS profile plane's heat is the uniform share of total population
  (profile-major queries are uniform across profiles by shape);
* a trace plane's priority is a fixed density (the toc only knows
  lengths, and trace bytes are proportional to samples, so traces cannot
  be differentiated from index data alone — they slot in below
  moderately hot data planes, above the cold tail);
* planes are admitted hottest-per-byte first until the budget is spent.

Everything here runs from summary statistics and index arrays alone; the
only plane I/O is the warming itself.

``owned`` restricts the plan to planes a predicate claims — how a shard
worker of :class:`repro.serve.shard.ShardedQueryServer` warms only the
planes the consistent-hash router will ever send it.  The predicate may
return a *weight* instead of a bool: replica-owned planes report a
fractional weight (``ConsistentHashRing.warm_priority``), scaling their
heat density down so primary-owned planes warm **hot** (first, as
before) and replica-owned planes warm behind every primary plane of
equal density — the replica tier fills whatever budget the primary tier
leaves.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.obs import monotime
from repro.query.database import Database

#: plan/ownership keys: ``(store, id)`` with store in _STORES
_STORES = ("cms", "pms", "trc")


def plan_warm(db: Database, byte_budget: int,
              owned: Callable[[str, int], bool] | None = None
              ) -> list[tuple[str, int, int]]:
    """Choose planes to preload: ``[(store, id, est_bytes), ...]``.

    Ranked by heat density (population per on-disk byte), computed from
    summary stats + store/trace indexes only — zero plane reads.
    ``est_bytes`` is the on-disk plane size, a stand-in for the decoded
    footprint.  ``owned(store, id)`` (optional) drops planes another shard
    is responsible for; a falsy return drops the plane, and a fractional
    weight (replica ownership) scales its density so it ranks behind
    primary-owned planes of equal heat.
    """
    stat = "count" if "count" in db.stats else "sum"
    ctx_heat = np.zeros(db.n_contexts, dtype=np.float64)
    if db.stats:
        np.add.at(ctx_heat, np.asarray(db.stats["ctx"], dtype=np.int64),
                  np.abs(np.asarray(db.stats[stat], dtype=np.float64)))
    total_heat = float(ctx_heat.sum())

    candidates: list[tuple[float, int, str, int, int]] = []
    if db._cms is not None:
        sizes = np.diff(db._cms.offsets.astype(np.int64))
        for ctx in np.flatnonzero(sizes > 0):
            heat = float(ctx_heat[ctx]) if ctx < ctx_heat.size else 0.0
            if heat > 0.0:
                candidates.append((heat / float(sizes[ctx]), 0, "cms",
                                   int(ctx), int(sizes[ctx])))
    pms_heat = total_heat / max(db.n_profiles, 1)
    for pid in range(db.n_profiles):
        sz = int(db._pms.index[pid, 1])
        if sz > 0 and pms_heat > 0.0:
            candidates.append((pms_heat / sz, 1, "pms", pid, sz))
    if db._trc is not None:
        from repro.core.traces import segment_nbytes
        # the toc only knows lengths, and segment bytes are proportional
        # to samples (12 B/sample) — so every trace plane has the *same*
        # heat density by construction.  Rank them at a deliberate
        # cross-store priority instead of pretending to differentiate:
        # half a sample-per-byte's worth (1/24) places traces below
        # moderately hot data planes but above the cold tail, and the
        # (store, pid) tiebreak keeps the order deterministic.
        trc_density = 1.0 / (2 * segment_nbytes(1))
        for pid in range(db._trc.n):
            n_samples = int(db._trc.toc[pid, 1])
            if n_samples > 0:
                candidates.append((trc_density, 2, "trc", pid,
                                   segment_nbytes(n_samples)))

    if owned is not None:
        weighted = []
        for dens, rank, store, oid, sz in candidates:
            w = owned(store, oid)
            if not w:
                continue
            weighted.append((dens * float(w), rank, store, oid, sz))
        candidates = weighted

    # hottest-per-byte first; (store, id) tiebreak keeps plans deterministic
    candidates.sort(key=lambda t: (-t[0], t[1], t[3]))
    plan, budget = [], int(byte_budget)
    for _, _, store, oid, sz in candidates:
        if sz > budget:
            continue
        plan.append((store, oid, sz))
        budget -= sz
    return plan


def warm_cache(db: Database, byte_budget: int | None = None, *,
               owned: Callable[[str, int], bool] | None = None) -> dict:
    """Execute :func:`plan_warm` against the Database's LRU; returns a
    report.  The budget is clamped to 90% of the cache capacity (leaving
    room for the live working set): warming past capacity would evict the
    hottest-per-byte planes it loaded first — worse than not warming."""
    cap = int(db.cache.capacity_bytes * 0.9)
    byte_budget = cap if byte_budget is None else min(int(byte_budget), cap)
    # monotime (not perf_counter): one clock for every duration the
    # serve stack reports, so warm timings compare against span timings
    t0 = monotime()
    plan = plan_warm(db, byte_budget, owned)
    loaded = {"cms": 0, "pms": 0, "trc": 0}
    evictions0 = db.cache.evictions
    for store, oid, _ in plan:
        if db.cache.nbytes >= byte_budget:
            break  # decoded footprints ran ahead of the on-disk estimate
        if db.cache.evictions != evictions0:
            break  # never trade already-warmed planes for colder ones
        if store == "cms":
            db.context_plane(oid)
        elif store == "pms":
            db.profile_metrics(oid)
        else:
            db.trace(oid)
        loaded[store] += 1
    return {"planned": len(plan), "loaded": sum(loaded.values()),
            "cms_planes": loaded["cms"], "pms_planes": loaded["pms"],
            "trc_planes": loaded["trc"],
            "cache_bytes": db.cache.nbytes, "budget_bytes": int(byte_budget),
            "seconds": round(monotime() - t0, 4)}
