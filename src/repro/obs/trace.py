"""Request tracing and the per-process flight recorder.

A **trace id** is minted at the HTTP edge (or accepted from an
``X-Trace-Id`` header / ``trace_id`` envelope field), stamped on every
:class:`~repro.serve.engine.QueryRequest` in the call, and rides the
request through the scheduler, across the shm/pickle transport into
shard workers, and back through replay-after-SIGKILL — the wire codec
ships it like any other request field.

A **span** is one timed phase of one request's life (``queue_wait``,
``dispatch``, ``decode``, ``encode``, ``merge``, ``replay``,
``request``), recorded into the process-local :class:`FlightRecorder`:
a bounded ring buffer that costs O(1) per span and can never grow.
Workers ship their freshly recorded spans piggybacked on reply
messages; the parent folds them into its own ring so ``GET
/debug/spans`` shows the whole fleet.  On worker death or a burst of
``QueryError`` results the recorder freezes a dump of the most recent
spans — the last seconds of history that led to the event.

Ring contents export through :mod:`repro.obs.export` into the repo's
own trace-plane format, so the server's execution is queryable with the
same timeline/occupancy ops it serves.
"""
from __future__ import annotations

import os
import re
import threading
from collections import deque
from dataclasses import dataclass, field

from repro.obs.clock import monotime

#: span phase names recorded by the serving stack (docs/observability.md);
#: "failover" marks a request re-dispatched to a live replica after its
#: owner died, "hedge" a duplicate dispatch fired at a replica after the
#: p99-derived hedge delay, "watch" one epoch evaluation by the
#: regression-watch service
SPAN_PHASES = ("request", "queue_wait", "dispatch", "decode", "encode",
               "merge", "replay", "failover", "hedge", "ingest", "watch")

_TRACE_ID_RE = re.compile(r"^[A-Za-z0-9._:\-]{1,64}$")


def mint_trace_id() -> str:
    """A fresh 16-hex-char trace id (64 random bits)."""
    return os.urandom(8).hex()


def valid_trace_id(tid) -> bool:
    """Accept only ids that are safe to log, ship, and echo in headers."""
    return isinstance(tid, str) and bool(_TRACE_ID_RE.match(tid))


@dataclass
class Span:
    """One timed phase of one request — picklable, so workers can ship
    spans to the parent on the existing reply transport."""

    trace_id: str
    name: str           # phase: one of SPAN_PHASES
    op: str             # query op ("stripe", "topk", ...) or transport verb
    t0: float           # monotime() at phase start (host-wide comparable)
    dur: float          # seconds
    pid: int            # os pid that recorded it
    shard: int = -1     # owning shard, -1 for the parent / unsharded
    attrs: dict | None = None

    def as_dict(self) -> dict:
        d = {"trace_id": self.trace_id, "name": self.name, "op": self.op,
             "t0": round(self.t0, 6), "dur_ms": round(self.dur * 1e3, 4),
             "pid": self.pid, "shard": self.shard}
        if self.attrs:
            d["attrs"] = self.attrs
        return d


@dataclass
class _Dump:
    reason: str
    t: float
    spans: list = field(default_factory=list)


class FlightRecorder:
    """Bounded ring of recent spans + a bounded outbox for shipping.

    ``capacity`` spans are retained (oldest evicted); ``0`` disables
    recording entirely (every ``record`` is a cheap no-op guarded by
    :attr:`enabled`, which is how the benchmark's traced-off leg pays
    nothing).  All methods are thread-safe; `record` is designed to sit
    on the serving hot path — one deque append under a lock.
    """

    #: retained per dump — the last moments before a death/error burst
    DUMP_SPANS = 128
    #: dumps retained (worker deaths can cluster)
    MAX_DUMPS = 8
    #: min seconds between dumps — an error storm must not spin freezing
    DUMP_INTERVAL_S = 1.0

    def __init__(self, capacity: int = 2048):
        self.capacity = max(0, int(capacity))
        #: stamped on spans recorded without an explicit shard — shard
        #: workers set it once at startup so every span they record
        #: (including ones from shared code like ``serve_one``) carries
        #: the owning shard without threading it through call sites
        self.default_shard = -1
        self._lock = threading.Lock()
        self._ring: deque[Span] = deque(maxlen=max(self.capacity, 1))
        # spans recorded here and not yet shipped to the parent process;
        # bounded separately so a quiet transport can't grow it
        self._outbox: deque[Span] = deque(maxlen=max(self.capacity, 1))
        self._dumps: deque[_Dump] = deque(maxlen=self.MAX_DUMPS)
        self._last_dump_t = -1e9
        self.recorded = 0        # total spans ever recorded (not bounded)
        self.dropped_outbox = 0  # outbox overwrites (ring keeps them)

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def record(self, name: str, op: str, t0: float, dur: float, *,
               trace_id: str = "", shard: int = -1,
               attrs: dict | None = None) -> None:
        """Record one locally-measured span (also queued for shipping)."""
        if not self.capacity:
            return
        if shard < 0:
            shard = self.default_shard
        span = Span(trace_id, name, op, t0, dur, os.getpid(), shard, attrs)
        with self._lock:
            if len(self._outbox) == self._outbox.maxlen:
                self.dropped_outbox += 1
            self._ring.append(span)
            self._outbox.append(span)
            self.recorded += 1

    def extend(self, spans) -> None:
        """Fold spans shipped from another process into the ring only
        (never re-shipped — the parent is the terminus)."""
        if not self.capacity or not spans:
            return
        with self._lock:
            self._ring.extend(spans)
            self.recorded += len(spans)

    def drain_outbox(self) -> list[Span]:
        """Take every span recorded since the last drain (workers call
        this when building a reply message)."""
        if not self.capacity:
            return []
        with self._lock:
            out = list(self._outbox)
            self._outbox.clear()
        return out

    def snapshot(self, limit: int | None = None) -> list[Span]:
        with self._lock:
            spans = list(self._ring)
        return spans if limit is None else spans[-limit:]

    def dump(self, reason: str) -> bool:
        """Freeze the most recent spans under ``reason`` (rate-limited)."""
        if not self.capacity:
            return False
        now = monotime()
        with self._lock:
            if now - self._last_dump_t < self.DUMP_INTERVAL_S:
                return False
            self._last_dump_t = now
            spans = list(self._ring)[-self.DUMP_SPANS:]
            self._dumps.append(
                _Dump(reason, now, [s.as_dict() for s in spans]))
        return True

    def as_dict(self, limit: int = 256) -> dict:
        """The ``GET /debug/spans`` body."""
        with self._lock:
            spans = list(self._ring)[-limit:]
            dumps = [{"reason": d.reason, "t": round(d.t, 6),
                      "n_spans": len(d.spans), "spans": d.spans}
                     for d in self._dumps]
        return {"capacity": self.capacity, "recorded": self.recorded,
                "dropped_outbox": self.dropped_outbox,
                "n": len(spans), "spans": [s.as_dict() for s in spans],
                "dumps": dumps}

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._outbox.clear()
            self._dumps.clear()
            self.recorded = 0
            self.dropped_outbox = 0
            self._last_dump_t = -1e9


def _default_capacity() -> int:
    try:
        return int(os.environ.get("REPRO_TRACE_RING", "2048"))
    except ValueError:
        return 2048


_recorder = FlightRecorder(_default_capacity())


def recorder() -> FlightRecorder:
    """The process-local flight recorder."""
    return _recorder


def configure(capacity: int) -> FlightRecorder:
    """Replace the process recorder (``0`` disables tracing).  Called by
    servers honoring ``--trace-ring`` and by shard workers at startup."""
    global _recorder
    _recorder = FlightRecorder(capacity)
    return _recorder
