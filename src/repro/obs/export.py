"""Export flight-recorder spans into the repo's own trace-plane format.

The profiler profiles itself: the spans a serving fleet records (see
:mod:`repro.obs.trace`) become ordinary :class:`MeasurementProfile`
files — one per recording process, identity ``kind="obs"`` — and run
through the standard :class:`StreamingAggregator` into a byte-compatible
analysis database.  After that, everything built for application
profiles works on the server's own execution:

* ``repro.launch.analyze query --db <out>/db window --t0 ... --t1 ...``
  returns the server's occupancy and hot phases over wall time;
* :func:`repro.query.timeline.samples_in_window` / ``occupancy`` give a
  per-process timeline of serve phases;
* ``topk`` over ``obs.time`` ranks ``/serve/<op>/<phase>`` call paths by
  where the seconds went (queue-wait vs dispatch vs decode vs encode).

Span-to-profile mapping:

* each (pid, shard) that recorded spans becomes one profile (rank =
  enumeration order, ``identity={"kind": "obs", "os_pid": ..,
  "shard": ..}``);
* a span becomes context ``/serve/<op>/<phase>`` — phase kind for the
  root, module kind for the op, op kind for the phase, mirroring the
  phase→module→op shape of application CCTs;
* metrics ``obs.time`` (summed seconds) and ``obs.count`` (spans) on
  that context;
* the trace section is the span sequence itself: one sample per span at
  its start time, normalized to the earliest span across *all*
  processes (``time.monotonic`` shares an epoch across processes on one
  host, so parent and worker spans interleave correctly on one axis).
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.core.cct import KIND_MODULE, KIND_OP, KIND_PHASE, ContextTree
from repro.core.metrics import MetricRegistry
from repro.core.sparse import MeasurementProfile, SparseMetrics, Trace


def spans_to_profiles(spans) -> list[MeasurementProfile]:
    """Convert spans (from :meth:`FlightRecorder.snapshot`) into one
    measurement profile per recording process."""
    if not spans:
        raise ValueError("no spans to export — is the trace ring enabled?")
    t_base = min(s.t0 for s in spans)
    by_proc: dict[tuple[int, int], list] = {}
    for s in spans:
        by_proc.setdefault((s.shard, s.pid), []).append(s)

    profiles = []
    for rank, key in enumerate(sorted(by_proc)):
        shard, pid = key
        group = sorted(by_proc[key], key=lambda s: s.t0)
        reg = MetricRegistry()
        m_time = reg.register("obs.time", "s", side="host")
        m_count = reg.register("obs.count", "", side="host")
        tree = ContextTree()
        ctx_ids, mids, vals = [], [], []
        trace_t, trace_c = [], []
        for s in group:
            cid = tree.path([(KIND_PHASE, "serve"),
                             (KIND_MODULE, s.op or "?"),
                             (KIND_OP, s.name)])
            ctx_ids += [cid, cid]
            mids += [m_time.mid, m_count.mid]
            vals += [s.dur, 1.0]
            trace_t.append(s.t0 - t_base)
            trace_c.append(cid)
        prof = MeasurementProfile(
            environment={"app": "repro-obs", "registry": reg.to_json(),
                         "obs": {"t_base": t_base}},
            identity={"rank": rank, "stream": 0, "kind": "obs",
                      "os_pid": pid, "shard": shard},
            file_paths=[],
            tree=tree,
            trace=Trace(np.asarray(trace_t, dtype=np.float64),
                        np.asarray(trace_c, dtype=np.uint32)),
            metrics=SparseMetrics.from_triplets(ctx_ids, mids, vals))
        profiles.append(prof)
    return profiles


def export_spans(spans, out_dir: str, *,
                 executor: str = "serial") -> dict:
    """Write span profiles under ``out_dir/profiles`` and aggregate them
    into a queryable database at ``out_dir/db``.  Returns a summary.
    """
    profiles = spans_to_profiles(spans)
    prof_dir = os.path.join(out_dir, "profiles")
    os.makedirs(prof_dir, exist_ok=True)
    paths = []
    for prof in profiles:
        path = os.path.join(prof_dir, f"obs-{prof.identity['rank']:04d}.rprf")
        prof.save(path)
        paths.append(path)
    db_dir = os.path.join(out_dir, "db")
    StreamingAggregator(db_dir, AggregationConfig(executor=executor)).run(paths)
    return {"db_dir": db_dir, "profiles": len(paths),
            "spans": len(spans),
            "t_base": min(s.t0 for s in spans),
            "t_span_s": round(max(s.t0 + s.dur for s in spans)
                              - min(s.t0 for s in spans), 6)}
