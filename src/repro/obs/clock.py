"""The one monotonic clock for the serve/ingest stack.

Every duration and span timestamp in the fleet comes from here.  On
Linux ``time.monotonic()`` is ``CLOCK_MONOTONIC`` — the same epoch in
every process on the host — so spans recorded in shard workers line up
with spans recorded in the HTTP front on a shared timeline, which is
what lets :mod:`repro.obs.export` build one coherent trace database out
of a multi-process server's flight recorders.

(`serve/warm.py` used to time with ``time.perf_counter()`` while the
rest of the stack used ``time.monotonic()``; mixing the two makes
cross-module latency numbers incomparable.  Import ``monotime`` instead
of picking a clock.)
"""
from __future__ import annotations

import time

monotime = time.monotonic
