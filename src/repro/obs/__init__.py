"""Self-hosted observability: metrics registry, tracing, flight recorder.

``repro.obs`` is the one substrate the serve/ingest fleet reports
through — see docs/observability.md for the metric tables, the span
taxonomy, and the self-profiling walkthrough.  :mod:`repro.obs.export`
is intentionally *not* imported here: shard workers import this package
on their hot path and must not pay for numpy-heavy export machinery
they never use.
"""
from repro.obs.clock import monotime
from repro.obs.registry import (
    HIST_EDGES_US,
    Counter,
    CounterGroup,
    Gauge,
    Histogram,
    HistogramFamily,
    MetricsRegistry,
)
from repro.obs.trace import (
    SPAN_PHASES,
    FlightRecorder,
    Span,
    configure,
    mint_trace_id,
    recorder,
    valid_trace_id,
)

__all__ = [
    "monotime",
    "HIST_EDGES_US",
    "Counter",
    "CounterGroup",
    "Gauge",
    "Histogram",
    "HistogramFamily",
    "MetricsRegistry",
    "SPAN_PHASES",
    "FlightRecorder",
    "Span",
    "configure",
    "mint_trace_id",
    "recorder",
    "valid_trace_id",
]
