"""Unified metrics registry for the serve/ingest fleet.

One thread-safe home for the counters, gauges, and latency histograms
that used to live as ad-hoc ``dict`` + ``Lock`` pairs in every module
(`serve/http.py`, `serve/scheduler.py`, `serve/shard.py`,
`query/database.py`, `ingest/server.py`).  Two render paths from the
same instruments:

* the existing JSON ``/metrics`` shapes — :class:`CounterGroup` is a
  real mapping and :class:`Histogram.as_dict` keeps its historical keys,
  so ``dict(group)`` / ``hist.as_dict()`` at the old call sites emit
  byte-identical JSON;
* Prometheus text exposition (``GET /metrics?format=prom``) via
  :meth:`MetricsRegistry.prometheus` / :meth:`MetricsRegistry.render`.

Locking discipline matches the code it replaces: single integer
increments on counters are lock-free under the GIL where the caller
already holds its own lock, and :class:`CounterGroup` carries its own
lock for callers that don't.
"""
from __future__ import annotations

import re
import threading
from collections.abc import MutableMapping

# histogram bucket upper edges in MICROseconds: 100us .. 3s, then +inf.
# (Identical to the scheduler's historical LatencyHistogram edges — the
# /metrics JSON shape depends on them.)
HIST_EDGES_US = (100.0, 300.0, 1e3, 3e3, 1e4, 3e4, 1e5, 3e5, 1e6, 3e6)

_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_SANITIZE.sub("_", name)


class Histogram:
    """Bounded latency histogram over fixed microsecond buckets.

    Lock-free under the GIL for single observations (list item increment
    is atomic enough for monitoring); cheap to snapshot.  This is the
    one histogram for the whole stack — ``serve/scheduler.py`` and
    ``serve/http.py`` used to carry their own copy as
    ``LatencyHistogram``, which remains importable as an alias.
    """

    __slots__ = ("counts", "total_s", "n")

    def __init__(self):
        self.counts = [0] * (len(HIST_EDGES_US) + 1)
        self.total_s = 0.0
        self.n = 0

    def observe(self, seconds: float) -> None:
        us = seconds * 1e6
        i = 0
        for edge in HIST_EDGES_US:
            if us < edge:
                break
            i += 1
        self.counts[i] += 1
        self.total_s += seconds
        self.n += 1

    def quantile(self, q: float) -> float:
        """Upper-edge estimate of quantile ``q`` in seconds."""
        if self.n == 0:
            return 0.0
        rank = q * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return (HIST_EDGES_US[i] if i < len(HIST_EDGES_US)
                        else HIST_EDGES_US[-1] * 10) / 1e6
        return HIST_EDGES_US[-1] * 10 / 1e6

    def as_dict(self) -> dict:
        return {"buckets_us": list(HIST_EDGES_US), "counts": list(self.counts),
                "n": self.n,
                "mean_ms": (self.total_s / self.n * 1e3) if self.n else 0.0,
                "p50_ms_le": self.quantile(0.5) * 1e3,
                "p99_ms_le": self.quantile(0.99) * 1e3}

    def _prom_lines(self, name: str, labels: str = "") -> list[str]:
        """Cumulative-bucket exposition lines (no HELP/TYPE header)."""
        counts = list(self.counts)          # snapshot (GIL-atomic copy)
        lines, cum = [], 0
        for edge, c in zip(HIST_EDGES_US, counts):
            cum += c
            le = repr(edge / 1e6)
            sep = "," if labels else ""
            lines.append(f'{name}_bucket{{{labels}{sep}le="{le}"}} {cum}')
        cum += counts[-1]
        sep = "," if labels else ""
        lines.append(f'{name}_bucket{{{labels}{sep}le="+Inf"}} {cum}')
        suffix = f"{{{labels}}}" if labels else ""
        lines.append(f"{name}_sum{suffix} {repr(self.total_s)}")
        lines.append(f"{name}_count{suffix} {cum}")
        return lines


class Counter:
    """A monotonically increasing counter with its own lock."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value


class Gauge:
    """A point-in-time value: either set explicitly or computed by ``fn``."""

    __slots__ = ("_fn", "_value")

    def __init__(self, fn=None):
        self._fn = fn
        self._value = 0.0

    def set(self, value) -> None:
        self._value = value

    @property
    def value(self):
        if self._fn is not None:
            try:
                return self._fn()
            except Exception:   # noqa: BLE001 - a dead backing object reads 0
                return 0.0
        return self._value


class CounterGroup(MutableMapping):
    """A named family of counters that *is* a mapping.

    Drop-in for the fleet's historical ``self.counters = {...}`` dicts:
    ``group[key] += 1``, ``dict(group)``, and ``group[key]`` all behave
    exactly like the dict they replace (so existing ``/metrics`` JSON
    shapes and tests are untouched), while the registry renders each key
    as a Prometheus series.  Keys named in ``gauges`` render as gauges
    (values that can go down, e.g. ``reopen_last_s``); the rest render
    as counters with a ``_total`` suffix.  Carries its own lock for
    callers without one; :meth:`inc` is the locked increment.
    """

    def __init__(self, initial: dict | None = None, gauges=()):
        self._lock = threading.Lock()
        self._data: dict = dict(initial or {})
        self._gauges = frozenset(gauges)

    def inc(self, key, n=1) -> None:
        with self._lock:
            self._data[key] = self._data.get(key, 0) + n

    def set(self, key, value) -> None:
        with self._lock:
            self._data[key] = value

    def __getitem__(self, key):
        return self._data[key]

    def __setitem__(self, key, value):
        self._data[key] = value

    def __delitem__(self, key):
        with self._lock:
            del self._data[key]

    def __iter__(self):
        return iter(dict(self._data))

    def __len__(self):
        return len(self._data)

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._data)


class HistogramFamily:
    """Label-keyed histograms (e.g. per-op latency).

    Mapping-shaped where the scheduler used a plain ``dict`` of
    histograms: ``family.setdefault(op, Histogram()).observe(dt)`` and
    ``{k: h.as_dict() for k, h in family.items()}`` both work unchanged.
    """

    def __init__(self, label: str = "op"):
        self.label = label
        self._lock = threading.Lock()
        self._children: dict[str, Histogram] = {}

    def labels(self, key: str) -> Histogram:
        h = self._children.get(key)
        if h is None:
            with self._lock:
                h = self._children.setdefault(key, Histogram())
        return h

    # dict-compatible surface for existing call sites
    def setdefault(self, key, default=None) -> Histogram:
        return self.labels(key)

    def __getitem__(self, key) -> Histogram:
        return self._children[key]

    def __contains__(self, key) -> bool:
        return key in self._children

    def __len__(self) -> int:
        return len(self._children)

    def items(self):
        return list(self._children.items())


class MetricsRegistry:
    """Creates + tracks instruments and renders them all as Prometheus text.

    Instruments are namespaced ``repro_<name>`` in the exposition;
    callers pick dotted or slashed names freely (sanitized to the
    Prometheus charset).  Each module owns its own registry with a
    distinct name prefix (``http.``, ``scheduler.``, ``shard.``,
    ``db.``, ``ingest.``) and the HTTP front concatenates them with
    :meth:`render` — no global singleton to fight over across processes.
    """

    namespace = "repro"

    def __init__(self):
        self._lock = threading.Lock()
        # name -> ("counter"|"gauge"|"hist"|"family"|"group", instrument)
        self._instruments: dict[str, tuple[str, object]] = {}

    def _register(self, name: str, kind: str, instrument):
        with self._lock:
            have = self._instruments.get(name)
            if have is not None:
                if have[0] != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {have[0]}")
                return have[1]
            self._instruments[name] = (kind, instrument)
            return instrument

    def counter(self, name: str) -> Counter:
        return self._register(name, "counter", Counter())

    def gauge(self, name: str, fn=None) -> Gauge:
        return self._register(name, "gauge", Gauge(fn))

    def histogram(self, name: str) -> Histogram:
        return self._register(name, "hist", Histogram())

    def histogram_family(self, name: str, label: str = "op") -> HistogramFamily:
        return self._register(name, "family", HistogramFamily(label))

    def group(self, prefix: str, initial: dict, gauges=()) -> CounterGroup:
        """A :class:`CounterGroup` whose keys render as
        ``repro_<prefix>_<key>[_total]`` series."""
        return self._register(prefix, "group", CounterGroup(initial, gauges))

    # -- exposition ---------------------------------------------------------

    def prometheus(self, labels: str = "") -> str:
        """Render every instrument as Prometheus text exposition 0.0.4.

        ``labels`` (e.g. ``tenant="team-a"``) is merged into every sample
        line — how a multi-tenant front exposes one registry per tenant
        under shared series names.  Repeated same-type ``# TYPE`` lines
        across tenants are valid exposition (and accepted by
        tools/check_prom.py); only *conflicting* redeclarations are not.
        """
        out: list[str] = []
        suffix = f"{{{labels}}}" if labels else ""
        with self._lock:
            items = sorted(self._instruments.items())
        for name, (kind, inst) in items:
            base = f"{self.namespace}_{_prom_name(name)}"
            if kind == "counter":
                out.append(f"# TYPE {base}_total counter")
                out.append(f"{base}_total{suffix} {inst.value}")
            elif kind == "gauge":
                out.append(f"# TYPE {base} gauge")
                out.append(f"{base}{suffix} {_num(inst.value)}")
            elif kind == "hist":
                out.append(f"# TYPE {base}_seconds histogram")
                out.extend(inst._prom_lines(f"{base}_seconds", labels))
            elif kind == "family":
                out.append(f"# TYPE {base}_seconds histogram")
                for key, h in inst.items():
                    label = f'{_prom_name(inst.label)}="{key}"'
                    if labels:
                        label = f"{labels},{label}"
                    out.extend(h._prom_lines(f"{base}_seconds", label))
            elif kind == "group":
                for key, val in sorted(inst.snapshot().items()):
                    series = f"{base}_{_prom_name(str(key))}"
                    if key in inst._gauges:
                        out.append(f"# TYPE {series} gauge")
                        out.append(f"{series}{suffix} {_num(val)}")
                    else:
                        out.append(f"# TYPE {series}_total counter")
                        out.append(f"{series}_total{suffix} {_num(val)}")
        return "\n".join(out) + "\n" if out else ""

    @staticmethod
    def render(registries, labels: str = "") -> str:
        """Concatenate several registries' expositions (``None`` skipped)."""
        return "".join(r.prometheus(labels) for r in registries
                       if r is not None)


def _num(v) -> str:
    """Prometheus sample value: ints stay ints, floats use repr."""
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    try:
        return repr(float(v))
    except (TypeError, ValueError):
        return "0"
