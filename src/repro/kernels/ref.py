"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segstats_ref(ids: jax.Array, vals: jax.Array, num_segments: int) -> jax.Array:
    """(S, 8) [sum, cnt, min, max, sumsq, 0, 0, 0]; empty segs -> min=+inf/max=-inf."""
    ids = ids.astype(jnp.int32)
    vals = vals.astype(jnp.float32)
    in_range = ids < num_segments
    safe = jnp.where(in_range, ids, 0)
    w = in_range.astype(jnp.float32)
    s = jax.ops.segment_sum(vals * w, safe, num_segments)
    c = jax.ops.segment_sum(w, safe, num_segments)
    q = jax.ops.segment_sum(vals * vals * w, safe, num_segments)
    mn = jax.ops.segment_min(jnp.where(in_range, vals, jnp.inf), safe, num_segments)
    mx = jax.ops.segment_max(jnp.where(in_range, vals, -jnp.inf), safe, num_segments)
    zero = jnp.zeros_like(s)
    return jnp.stack([s, c, mn, mx, q, zero, zero, zero], axis=1)


def blockscan_ref(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x, axis=0)


def scatter_add_ref(ids: jax.Array, vals: jax.Array, num_segments: int) -> jax.Array:
    ids = ids.astype(jnp.int32)
    in_range = ids < num_segments
    safe = jnp.where(in_range, ids, 0)
    w = in_range.astype(vals.dtype)[:, None]
    return jax.ops.segment_sum(vals * w, safe, num_segments).astype(jnp.float32)


def int8_quant_ref(x: jax.Array, block_n: int):
    xb = x.reshape(-1, block_n)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    err = xb - q.astype(x.dtype) * scale[:, None]
    return q.reshape(-1), scale.astype(jnp.float32), err.reshape(-1)
