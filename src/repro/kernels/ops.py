"""Jit'd public wrappers for the Pallas kernels.

Wrappers own padding/alignment (block-multiple lengths, out-of-range
sentinel ids) and backend selection: on TPU the compiled kernels run
natively; on the CPU container they execute under ``interpret=True`` so
every test validates the actual kernel bodies against the jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import blockscan as _bs
from repro.kernels import int8_quant as _q8
from repro.kernels import scatter_add as _sc
from repro.kernels import segstats as _ss


LANE = 128     # minor-dim tile multiple (f32, TPU v4/v5)
SUBLANE = 8    # second-minor tile multiple (f32)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _align_up(x: int, mult: int) -> int:
    return -(-int(x) // mult) * mult


def _clamp_block(requested: int, n: int, align: int) -> int:
    """Clamp a block size to the problem size without breaking TPU tiling.

    A plain ``min(requested, max(align, n))`` can produce block sizes like
    200 that pass ``interpret=True`` but are illegal BlockSpecs on real
    hardware (the lane dim must be a multiple of 128, sublanes of 8): the
    clamp is rounded *up* to the alignment, and padding covers the slack.
    """
    b = min(int(requested), max(align, int(n)))
    b = max(align, _align_up(b, align))
    assert b % align == 0 and b > 0, (requested, n, align, b)
    return b


def _pad_to(x: jax.Array, mult: int, fill) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


@functools.partial(jax.jit, static_argnames=("num_segments", "block_n", "block_s"))
def segstats(ids: jax.Array, vals: jax.Array, num_segments: int,
             block_n: int = _ss.DEFAULT_BLOCK_N,
             block_s: int = _ss.DEFAULT_BLOCK_S) -> jax.Array:
    """Segmented stats (S, 8): [sum, cnt, min, max, sumsq, ...].

    ``ids`` sorted ascending int32; values f32.  Empty segments finalize to
    min=max=0 (matching :class:`repro.core.stats.StatsAccumulator`).
    """
    block_s = _clamp_block(block_s, num_segments, LANE)
    ids = _pad_to(ids.astype(jnp.int32), block_n, num_segments)
    vals = _pad_to(vals.astype(jnp.float32), block_n, 0)
    out = _ss.segstats_pallas(ids, vals, num_segments, block_n=block_n,
                              block_s=block_s, interpret=_interpret())
    out = out[:num_segments]
    empty = out[:, 1] == 0
    out = out.at[:, 2].set(jnp.where(empty, 0.0, out[:, 2]))
    out = out.at[:, 3].set(jnp.where(empty, 0.0, out[:, 3]))
    return out


@functools.partial(jax.jit, static_argnames=("block_n",))
def blockscan(x: jax.Array, block_n: int = _bs.DEFAULT_BLOCK_N) -> jax.Array:
    """Inclusive prefix sum along axis 0; accepts (N,) or (N, M)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    n = x.shape[0]
    block_n = _clamp_block(block_n, n, SUBLANE)
    xp = _pad_to(x, block_n, 0)
    out = _bs.blockscan_pallas(xp, block_n=block_n, interpret=_interpret())[:n]
    return out[:, 0] if squeeze else out


def exclusive_scan(x: jax.Array) -> jax.Array:
    """Exclusive scan with total appended: (N,) -> (N+1,); CMS offsets."""
    inc = blockscan(x)
    return jnp.concatenate([jnp.zeros((1,) + x.shape[1:], inc.dtype), inc])


@functools.partial(jax.jit, static_argnames=("num_segments", "block_n", "block_s"))
def scatter_add(ids: jax.Array, vals: jax.Array, num_segments: int,
                block_n: int = _sc.DEFAULT_BLOCK_N,
                block_s: int = _sc.DEFAULT_BLOCK_S) -> jax.Array:
    """out[s] += vals[ids == s]; vals (N,) or (N, M); unsorted ids allowed."""
    block_s = _clamp_block(block_s, num_segments, LANE)
    squeeze = vals.ndim == 1
    if squeeze:
        vals = vals[:, None]
    ids = _pad_to(ids.astype(jnp.int32), block_n, num_segments)
    vals = _pad_to(vals.astype(jnp.float32), block_n, 0)
    out = _sc.scatter_add_pallas(ids, vals, num_segments, block_n=block_n,
                                 block_s=block_s, interpret=_interpret())
    out = out[:num_segments]
    return out[:, 0] if squeeze else out


def histogram(ids: jax.Array, num_segments: int) -> jax.Array:
    return scatter_add(ids, jnp.ones(ids.shape[0], jnp.float32), num_segments)


@functools.partial(jax.jit, static_argnames=("block_n",))
def int8_quant(x: jax.Array, block_n: int = _q8.DEFAULT_BLOCK_N):
    """Block-scaled int8 quantization: (q, scales, err); pads internally."""
    n = x.shape[0]
    block_n = _clamp_block(block_n, n, LANE)
    xp = _pad_to(x.astype(jnp.float32), block_n, 0)
    q, s, e = _q8.int8_quant_pallas(xp, block_n=block_n, interpret=_interpret())
    return q[:n], s, e[:n]


def int8_dequant(q: jax.Array, scales: jax.Array, n: int,
                 block_n: int = _q8.DEFAULT_BLOCK_N) -> jax.Array:
    """Invert :func:`int8_quant`: ``q`` are the first ``n`` quantized values
    (the wrapper trims its padding), ``scales`` one f32 per ``block_n``
    block.  ``block_n`` must match the quantization call — both resolve it
    through the same clamp, so passing the same ``n`` suffices."""
    block_n = _clamp_block(block_n, n, LANE)
    npad = scales.shape[0] * block_n
    pad = npad - q.shape[0]
    if pad < 0:
        raise ValueError(
            f"int8_dequant: {q.shape[0]} quantized values exceed the "
            f"capacity of {scales.shape[0]} scale blocks x block_n="
            f"{block_n} ({npad}); scales/block_n do not match the "
            f"int8_quant call that produced them")
    qp = jnp.concatenate([q, jnp.zeros(pad, q.dtype)]) if pad else q
    full = (qp.astype(jnp.float32).reshape(-1, block_n) * scales[:, None]).reshape(-1)
    return full[:n]


# -- composite: the propagation primitive (paper §4.1.2, DESIGN.md §4) -------

def inclusive_from_exclusive(dense_preorder: jax.Array, end: jax.Array) -> jax.Array:
    """inclusive[i] = cumsum[end[i]] - cumsum[i] over preorder values (N, M)."""
    inc = blockscan(dense_preorder)
    ps = jnp.concatenate([jnp.zeros((1, dense_preorder.shape[1]), inc.dtype), inc])
    n = dense_preorder.shape[0]
    return ps[end] - ps[jnp.arange(n)]
