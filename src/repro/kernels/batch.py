"""Device-offloaded aggregation: the batching layer between the phase-2
streaming engine and the Pallas kernels (ROADMAP item 3).

The idiom is MaxText-offline-inference-shaped: requests are coalesced and
padded into a small set of **shape classes** (power-of-two column buckets),
so many profiles' fused-transform work becomes one device launch per batch
instead of one per profile, and the jit cache stays bounded no matter how
many distinct profile shapes stream through.  Three hot loops route here:

* **inclusive propagation** — the O(n_ctx x m) cumsum of the fused kernel
  becomes a batched :func:`repro.kernels.ops.inclusive_from_exclusive`
  launch: all profiles share the unified tree's preorder length ``n``, so
  their dense exclusive matrices concatenate along columns into one
  ``(n, M_total)`` blockscan.  Prefix sums are column-independent, so a
  profile's result is a pure function of its own columns — **batch
  composition cannot perturb bytes**, which is what keeps the device path
  deterministic across executors and shard counts.
* **duplicate-key combine** — the stable-sorted segment sums behind
  :func:`repro.core.pipeline._combine_sorted` dispatch to the ``segstats``
  one-hot MXU kernel.  These launch per-profile (never concatenated:
  moving value-block boundaries would change f32 summation order with
  batch composition), with sizes padded to power-of-two buckets.
* **CMS stripe offsets / census** — the §4.3.2 exclusive scan runs through
  ``ops.exclusive_scan`` on int32 (exact, so CMS bytes never change), and
  the census histogram through ``ops.histogram`` on real accelerators.

Per-profile summary *statistics* do not offload: after the combine, each
profile's (ctx, mid) keys are unique, so the per-profile "stats" are the
identity (v, 1, v, v, v^2) — the real reduction is the cross-profile merge,
which :class:`repro.runtime.reduce.AsyncStreamingReducer` moves off the
consume thread instead.

Dtype contract (asserted per-plane by tests/test_pipeline.py): device
accumulation is f32.  A plane classifies as **"exact"** when every value is
an integer and both ``sum(|v|)`` and ``sum(v^2)`` stay within 2^24 — then
every partial sum is exactly representable in f32 regardless of
association order and device output is byte-identical to the CPU f64 path.
Anything else is **"f32"**: device values carry f32 rounding (and near-zero
inclusive sums may round to exactly 0.0 and drop out of the sparse plane).
The class is a pure function of the plane, never of the executor or batch,
so either way all backends agree byte-for-byte *with each other*.

Threading: the cross-thread coalescer is a combining funnel — no timers,
no dedicated dispatch thread.  A requester that finds no launch in flight
becomes the launcher and drains the pending list until it is empty; all
other requesters park on an event.  Device dispatch releases the GIL, which
is precisely what rescues the ``threads`` executor (its argsort-bound 1.56x
vs 1.91x-serial deficit, ROADMAP item 3).
"""
from __future__ import annotations

import threading

import numpy as np

LANE = 128     # minor-dim tile multiple (f32)
SUBLANE = 8    # second-minor tile multiple (f32)

# below this many values the CPU bincount beats a kernel launch even on a
# real accelerator; a constant, so the offload decision is a pure function
# of the plane (executor/batch independent)
DEVICE_COMBINE_MIN = 4096

# f32 integer-exactness ceiling: 2^24 (see module docstring)
_EXACT_LIMIT = 2.0 ** 24


def device_available() -> bool:
    """jax importable at all (the container bakes it in; stubbed envs may
    not)."""
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def has_accelerator() -> bool:
    """A real accelerator backend (TPU/GPU) — not the CPU client."""
    if not device_available():
        return False
    import jax
    return jax.default_backend() not in ("cpu",)


def device_ok(allow_interpret: bool = False) -> bool:
    """Can ``compute="device"`` run here?  Yes with a real accelerator;
    on a CPU-only host only when the caller opted into the interpret-mode
    proxy (tests and benches do; production configs fall back to cpu)."""
    return has_accelerator() or (allow_interpret and device_available())


def classify_plane(vals) -> str:
    """The per-plane dtype contract: ``"exact"`` or ``"f32"`` (docstring
    above).  Pure function of the values — every executor, shard count and
    batch composition classifies a given plane identically."""
    v = np.asarray(vals, dtype=np.float64)
    if v.size == 0:
        return "exact"
    if not np.all(np.isfinite(v)) or np.any(v != np.rint(v)):
        return "f32"
    a = np.abs(v)
    if a.sum() > _EXACT_LIMIT or np.sum(a * a) > _EXACT_LIMIT:
        return "f32"
    return "exact"


def _bucket(x: int, floor: int) -> int:
    """Next power-of-two >= max(x, floor): the shape-class ladder that keeps
    jit recompiles O(log(max size)) instead of O(distinct sizes)."""
    b = int(floor)
    x = int(x)
    while b < x:
        b *= 2
    return b


class _Request:
    __slots__ = ("cols", "out", "err", "event")

    def __init__(self, cols: np.ndarray):
        self.cols = cols
        self.out: np.ndarray | None = None
        self.err: BaseException | None = None
        self.event = threading.Event()


class DeviceAggregator:
    """Per-run device context: the unified tree's ``end`` array resident on
    device, the power-of-two shape-class jit cache, and the combining
    funnel that coalesces concurrent threads' inclusive-propagation work
    into single launches.

    One instance serves one phase-2 run: shared by all worker threads on
    the in-process path, one per worker process on the sharded path (where
    each worker is single-threaded, so batches degenerate to size 1 but
    keep the identical arithmetic — composition independence makes that a
    non-event for output bytes).
    """

    def __init__(self, end: np.ndarray, *, offload_combine: bool | None = None,
                 combine_min: int = DEVICE_COMBINE_MIN):
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops

        self._jnp = jnp
        self._ops = ops
        end = np.ascontiguousarray(np.asarray(end, dtype=np.int64))
        if end.size and int(end.max()) > np.iinfo(np.int32).max:
            raise ValueError("unified tree too large for int32 device ids")
        self.n = int(end.size)
        self._end_dev = jax.device_put(jnp.asarray(end.astype(np.int32)))
        self._incl_fn = jax.jit(ops.inclusive_from_exclusive)
        self.interpret = not has_accelerator()
        # the one-hot combine is MXU free-lunch on hardware but O(n*S) host
        # work under the interpret proxy, so it defaults off there; tests
        # force it on tiny planes to validate the wiring
        self.offload_combine = (not self.interpret if offload_combine is None
                                else bool(offload_combine))
        self.combine_min = int(combine_min)

        self._lock = threading.Lock()
        self._pending: list[_Request] = []
        self._launching = False
        # observability (reported through AnalysisResult.timings)
        self.launches = 0
        self.requests = 0

    # -- inclusive propagation (the batched hot loop) ------------------------

    def inclusive(self, cols: np.ndarray) -> np.ndarray:
        """``out[i, c] = sum(cols[i:end[i], c])`` for each column — the
        preorder-interval inclusive sums, f32.  Thread-safe; concurrent
        callers' columns ride one launch."""
        req = _Request(np.ascontiguousarray(cols, dtype=np.float32))
        with self._lock:
            self._pending.append(req)
            self.requests += 1
            i_launch = not self._launching
            if i_launch:
                self._launching = True
        if i_launch:
            while True:
                with self._lock:
                    batch = self._pending
                    self._pending = []
                    if not batch:
                        self._launching = False
                        break
                self._launch(batch)
        req.event.wait()
        if req.err is not None:
            raise req.err
        return req.out

    def _launch(self, batch: list[_Request]) -> None:
        try:
            widths = [r.cols.shape[1] for r in batch]
            mat = (batch[0].cols if len(batch) == 1
                   else np.concatenate([r.cols for r in batch], axis=1))
            out = self._inclusive_padded(mat)
            self.launches += 1
            o = 0
            for r, w in zip(batch, widths):
                r.out = out[:, o:o + w]
                o += w
        except BaseException as e:
            for r in batch:
                r.err = e
        finally:
            for r in batch:
                r.event.set()

    def _inclusive_padded(self, mat: np.ndarray) -> np.ndarray:
        n, m = mat.shape
        mb = _bucket(m, SUBLANE)
        if mb != m:  # zero columns: cumsum is column-local, results unchanged
            mat = np.concatenate(
                [mat, np.zeros((n, mb - m), dtype=np.float32)], axis=1)
        out = self._incl_fn(self._jnp.asarray(mat), self._end_dev)
        return np.asarray(out)[:, :m]

    # -- duplicate-key combine (per-profile segment sums) --------------------

    def wants_combine(self, n_values: int) -> bool:
        return self.offload_combine and n_values >= self.combine_min

    def combine_sums(self, seg_sorted: np.ndarray, vals: np.ndarray
                     ) -> np.ndarray:
        """Segment sums over stable-sorted dense ranks via the ``segstats``
        MXU kernel; f32 accumulation (see the module dtype contract).
        Launches are per-profile with bucket-padded shapes: concatenating
        different profiles' value streams would move block boundaries and
        change f32 summation order with batch composition."""
        x = int(seg_sorted.size)
        n_seg = int(seg_sorted[-1]) + 1 if x else 0
        if n_seg == 0:
            return np.zeros(0, dtype=np.float64)
        sb = _bucket(n_seg, LANE)
        xb = _bucket(x, LANE)
        ids = np.full(xb, sb, dtype=np.int32)  # sentinel: matches no segment
        ids[:x] = seg_sorted
        v = np.zeros(xb, dtype=np.float32)
        v[:x] = vals
        out = self._ops.segstats(self._jnp.asarray(ids),
                                 self._jnp.asarray(v), sb)
        self.launches += 1
        return np.asarray(out[:n_seg, 0], dtype=np.float64)


# ---------------------------------------------------------------------------
# CMS helpers (module-level: no per-run state needed)
# ---------------------------------------------------------------------------

def device_offsets(sizes: np.ndarray) -> np.ndarray | None:
    """CMS stripe offsets by device exclusive scan (paper §4.3.2), int32
    (the container runs without x64; f32 would corrupt offsets > 2^24).
    Integer cumsum is exact, so the result is byte-identical to
    ``np.cumsum`` and CMS output bytes never depend on the backend.
    Returns None (caller falls back to numpy) when jax is unavailable or
    the total would overflow int32 — decisions that depend only on the
    sizes, so every executor path makes them identically."""
    if not device_available():
        return None
    sizes = np.asarray(sizes, dtype=np.int64)
    if sizes.size == 0 or int(sizes.sum()) >= np.iinfo(np.int32).max:
        return None
    import jax.numpy as jnp

    from repro.kernels import ops
    out = ops.exclusive_scan(jnp.asarray(sizes.astype(np.int32)))
    return np.asarray(out, dtype=np.int64)


def device_census_counts(rows_all: np.ndarray, n_ctx: int) -> np.ndarray | None:
    """Per-context value counts via the one-hot ``histogram`` kernel — one
    launch over every profile's concatenated rows (unsorted ids are fine
    for scatter_add).  Real accelerators only: the O(values x contexts)
    mask work is MXU throwaway on TPU but a dealbreaker on the interpret
    proxy.  Counts are integers < 2^24 (guarded), so f32 accumulation is
    exact and the result matches ``np.add.at`` byte-for-byte."""
    if not has_accelerator() or n_ctx == 0:
        return None
    rows_all = np.asarray(rows_all)
    if rows_all.size >= 1 << 24:  # f32 count-exactness guard
        return None
    import jax.numpy as jnp

    from repro.kernels import ops
    counts = ops.histogram(jnp.asarray(rows_all.astype(np.int32)), int(n_ctx))
    return np.asarray(counts, dtype=np.int64)
