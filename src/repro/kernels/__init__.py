"""Pallas TPU kernels for the paper's aggregation hot spots.

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec VMEM tiling),
wrapped in :mod:`repro.kernels.ops` (jit + padding + backend selection) and
oracled by :mod:`repro.kernels.ref` (pure jnp).  Validated on CPU via
``interpret=True``; BlockSpecs target TPU v5e (8x128 lanes, 16 MiB VMEM).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
