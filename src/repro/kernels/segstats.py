"""Pallas TPU kernel: segmented statistics over sorted segment ids.

The aggregation hot spot of the paper (§4.1.2/§4.2.2): accumulate
{sum, count, min, max, sum-of-squares} of metric values per (context,
metric) key.  The CPU implementation uses per-context hash tables with
relaxed atomic accumulators; TPUs have neither hash tables nor atomics, so
the TPU-native formulation is a **tiled one-hot reduction**:

* grid = (segment tiles, value blocks), segment tile outer so every output
  tile sees its value blocks consecutively (legal TPU output revisiting);
* for a value block ``v (B,)`` with ids ``s (B,)`` and segment tile
  ``[j*T, (j+1)*T)``: ``mask = (s[:, None] == j*T + iota(T))`` — a (B, T)
  VMEM tile; ``sum/cnt/sumsq`` are ``mask^T @ {v, 1, v^2}`` contractions
  that run on the MXU; min/max are masked VPU reductions.

Arithmetic intensity: each value block is read once from HBM per segment
tile (nb*ns*B*4 bytes) and does O(B*T) MXU work — for T ≤ 1k the extra
flops are far below the 197 TF/s roof while avoiding HBM-bound
gather/scatter, which TPUs lack.

Block sizes (v5e): B=512 values x T=512 segments -> mask tile is
512x512xf32 = 1 MiB of VMEM (~3 MiB total working set), well inside the
16 MiB/core budget and 128-aligned on both MXU operand dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512   # values per block
DEFAULT_BLOCK_S = 512   # segments per tile

# output rows are padded to a lane-aligned 8 columns:
# [sum, cnt, min, max, sumsq, 0, 0, 0]
N_STATS = 8


def _segstats_kernel(ids_ref, val_ref, out_ref, *, block_s: int):
    j = pl.program_id(0)  # segment tile (outer)
    i = pl.program_id(1)  # value block (inner)

    @pl.when(i == 0)
    def _init():
        out = jnp.zeros_like(out_ref)
        out_ref[...] = out.at[:, 2].set(jnp.inf).at[:, 3].set(-jnp.inf)

    ids = ids_ref[...]            # (B,) int32 (global segment ids, sorted)
    vals = val_ref[...]           # (B,) f32
    seg0 = j * block_s
    local = ids - seg0
    cols = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_s), 1)
    mask = (local[:, None] == cols).astype(vals.dtype)     # (B, T)
    # MXU contractions
    s = jnp.dot(mask.T, vals, preferred_element_type=jnp.float32)
    c = jnp.sum(mask, axis=0)
    q = jnp.dot(mask.T, vals * vals, preferred_element_type=jnp.float32)
    # VPU masked min/max
    big = jnp.asarray(jnp.inf, vals.dtype)
    mn = jnp.min(jnp.where(mask > 0, vals[:, None], big), axis=0)
    mx = jnp.max(jnp.where(mask > 0, vals[:, None], -big), axis=0)

    out = out_ref[...]
    out_ref[...] = jnp.stack(
        [out[:, 0] + s, out[:, 1] + c,
         jnp.minimum(out[:, 2], mn), jnp.maximum(out[:, 3], mx),
         out[:, 4] + q,
         out[:, 5], out[:, 6], out[:, 7]],
        axis=1,
    )


def segstats_pallas(ids: jax.Array, vals: jax.Array, num_segments: int,
                    *, block_n: int = DEFAULT_BLOCK_N,
                    block_s: int = DEFAULT_BLOCK_S,
                    interpret: bool = False) -> jax.Array:
    """Returns (num_segments_padded, 8) [sum, cnt, min, max, sumsq, ...].

    ``ids`` must be sorted ascending; callers pad ``ids`` with an
    out-of-range sentinel (>= num_segments) — sentinel rows match no
    segment tile and contribute nothing.
    """
    n = ids.shape[0]
    assert n % block_n == 0, "ops wrapper pads to block multiple"
    s_pad = -(-num_segments // block_s) * block_s
    grid = (s_pad // block_s, n // block_n)
    out = pl.pallas_call(
        functools.partial(_segstats_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_s, N_STATS), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, N_STATS), jnp.float32),
        interpret=interpret,
    )(ids, vals)
    return out
