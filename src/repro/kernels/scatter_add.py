"""Pallas TPU kernel: scatter-add as tiled one-hot MXU contraction.

Used for (a) context histograms in the CMS size census (paper §4.3.2) and
(b) densifying a profile's sparse rows onto the unified preorder vector
before propagation.  TPUs have no scatter unit; the canonical formulation
is ``one_hot(idx)^T @ vals`` per (segment tile, value block), accumulated
over value blocks — all MXU work on 128-aligned tiles.

Unlike :mod:`repro.kernels.segstats` this kernel does **not** require
sorted indices (histograms aren't sorted); it trades that generality for
doing only the sum statistic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512
DEFAULT_BLOCK_S = 512


def _scatter_kernel(ids_ref, val_ref, out_ref, *, block_s: int):
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]
    vals = val_ref[...]                               # (B, M)
    local = ids - j * block_s
    cols = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_s), 1)
    onehot = (local[:, None] == cols).astype(vals.dtype)   # (B, T)
    out_ref[...] += jnp.dot(onehot.T, vals, preferred_element_type=jnp.float32)


def scatter_add_pallas(ids: jax.Array, vals: jax.Array, num_segments: int,
                       *, block_n: int = DEFAULT_BLOCK_N,
                       block_s: int = DEFAULT_BLOCK_S,
                       interpret: bool = False) -> jax.Array:
    """out[s, :] = sum of vals rows with ids == s; (S_pad, M) f32 output.

    Out-of-range ids (sentinel padding) contribute nothing.
    """
    n = ids.shape[0]
    m = vals.shape[1]
    assert n % block_n == 0
    s_pad = -(-num_segments // block_s) * block_s
    grid = (s_pad // block_s, n // block_n)
    return pl.pallas_call(
        functools.partial(_scatter_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda j, i: (i,)),
            pl.BlockSpec((block_n, m), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, m), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, m), jnp.float32),
        interpret=interpret,
    )(ids, vals)
