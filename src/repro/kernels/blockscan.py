"""Pallas TPU kernel: blocked multi-column prefix sum.

The propagation hot spot (paper §4.1.2) after the preorder rewrite
(DESIGN.md §4): inclusive metric costs are ``cumsum[end[i]] - cumsum[i]``
over the preorder-scattered exclusive values, and CMS offsets (§4.3.2) are
an exclusive scan over per-context sizes.  Both reduce to one long prefix
sum.

TPU shape: grid iterates value blocks sequentially (TPU grids are
sequential per core), carrying the running block total in a VMEM scratch
accumulator — the parallel-scan "carry" without atomics.  Rows are tiled
(block_n x M); M is the number of metrics a profile observed (small), kept
whole in-line so the scan is one pass over HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 1024


def _scan_kernel(x_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...]                       # (B, M)
    c = carry_ref[...]                   # (1, M)
    s = jnp.cumsum(x, axis=0) + c        # inclusive within block + carry
    o_ref[...] = s
    carry_ref[...] = s[-1:, :]


def blockscan_pallas(x: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                     interpret: bool = False) -> jax.Array:
    """Inclusive prefix sum along axis 0 of (N, M); N % block_n == 0."""
    n, m = x.shape
    assert n % block_n == 0
    return pl.pallas_call(
        _scan_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, m), x.dtype)],
        interpret=interpret,
    )(x)
