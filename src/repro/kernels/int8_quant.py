"""Pallas TPU kernel: block-wise symmetric int8 quantization.

Distributed-optimization substrate (DESIGN.md §8): gradients crossing the
pod boundary (the slow DCN-analog hop) are compressed with block-scaled
int8 + error feedback.  Each 1-D block of ``block_n`` values gets one f32
scale ``max(|x|)/127``; the residual (feedback) is returned so the
optimizer can fold it into the next step.

VMEM: a (block_n,) f32 tile + int8 output tile; block_n = 2048 keeps both
lanes-aligned and trivially resident.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048


def _quant_kernel(x_ref, q_ref, scale_ref, err_ref):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    scale_ref[...] = jnp.reshape(scale, scale_ref.shape).astype(jnp.float32)
    err_ref[...] = x - q.astype(x.dtype) * scale


def int8_quant_pallas(x: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = False):
    """x (N,) f32, N % block_n == 0 -> (q int8 (N,), scales f32 (N/B,), err f32 (N,))."""
    n = x.shape[0]
    assert n % block_n == 0
    nb = n // block_n
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(x)


def int8_dequant(q: jax.Array, scales: jax.Array, block_n: int = DEFAULT_BLOCK_N) -> jax.Array:
    return (q.astype(jnp.float32).reshape(-1, block_n)
            * scales[:, None]).reshape(-1)
