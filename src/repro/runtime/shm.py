"""Shared-memory slab transport for the ``processes`` backend.

The legacy plane path pickles every encoded plane through the pool's result
pipe: serialize in the worker, copy through a socket, deserialize in the
parent — three copies plus syscalls per plane.  This module replaces that
with a parent-owned arena of fixed-size shared-memory *slabs*:

* the parent creates ``n_slabs`` segments up front and assigns a free slab
  to each task **at submission time, in index order**;
* the worker writes the encoded plane (and trace/statistics sections)
  straight into the slab — ``SparseMetrics.encode_into`` serializes into
  the mapping, so the only copy left is the final write-buffer append in
  the parent — and ships a tiny ``(slab, lengths)`` descriptor back;
* the parent consumes planes in profile order and *recycles* the slab.

Because slabs are assigned in index order and only recycled on in-order
consumption, slab exhaustion throttles submission: at most ``n_slabs``
profiles are in flight (worker-resident or buffered out-of-order), and the
next-expected profile always already owns a slab — so the ordered sink can
run a bounded window with no self-deadlock (the ROADMAP known limit on the
sharded path).  Planes larger than a slab fall back to a dedicated one-shot
segment created by the worker and unlinked by the parent after use.

``attach`` avoids resource-tracker re-registration where the runtime
supports it (``track=False``, 3.13+).  On older runtimes the attach-side
``register`` is a harmless set-dedupe: workers share the parent's tracker
process (the fd is inherited on both fork and spawn starts), so the name
stays registered exactly until the creator unlinks it.
"""
from __future__ import annotations

from multiprocessing import shared_memory

import numpy as np

_ALIGN = 8


def attach(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment (the creator owns unlinking; see the
    module docstring on tracker accounting)."""
    try:
        return shared_memory.SharedMemory(name=name, track=False)  # 3.13+
    except TypeError:
        return shared_memory.SharedMemory(name=name)


def create_segment(size: int) -> shared_memory.SharedMemory:
    """A dedicated one-shot segment (oversize-plane fallback)."""
    return shared_memory.SharedMemory(create=True, size=max(int(size), 1))


def destroy_segment(seg: shared_memory.SharedMemory) -> None:
    """Close + unlink a segment through *any* handle, keeping the resource
    tracker consistent.

    One-shot segments are created by a worker but unlinked by the parent's
    attach handle.  On 3.13+ that handle is untracked (``track=False``), so
    its ``unlink`` skips ``resource_tracker.unregister`` — but the worker's
    *create* did register with the shared tracker, which would report the
    segment as leaked at shutdown.  Unregister explicitly in that case; on
    older runtimes ``unlink`` already unregisters, and doing it twice would
    make the tracker log spurious KeyErrors.
    """
    seg.close()
    try:
        seg.unlink()
    except FileNotFoundError:
        pass
    if getattr(seg, "_track", True) is False:
        try:
            from multiprocessing import resource_tracker
            resource_tracker.unregister(
                getattr(seg, "_name", "/" + seg.name), "shared_memory")
        except Exception:
            pass


def sections_layout(lengths) -> tuple[list[int], int]:
    """8-byte-aligned section offsets for a slab payload.

    Writer (worker) and reader (parent) both derive offsets from the same
    section lengths, so only the lengths travel in the descriptor.
    Alignment keeps ``np.frombuffer`` views on every section aligned.
    """
    offs = []
    off = 0
    for ln in lengths:
        offs.append(off)
        off += -(-int(ln) // _ALIGN) * _ALIGN
    return offs, off


def write_section(buf, off: int, arr: np.ndarray) -> None:
    """Copy one array into the slab at ``off`` (dtype preserved)."""
    if arr.size:
        dst = np.frombuffer(buf, dtype=arr.dtype, count=arr.size, offset=off)
        dst[:] = arr


def read_section(buf, off: int, dtype, count: int, *, copy: bool = False):
    """View (or copy) one section; copy when the array must outlive the
    slab's recycling — e.g. statistics arrays held by the stats reducer."""
    arr = np.frombuffer(buf, dtype=dtype, count=count, offset=off)
    return arr.copy() if copy else arr


class SlabArena:
    """Parent-owned pool of equal-size shared-memory slabs.

    Single-threaded by design: ``acquire``/``release`` are called only from
    the parent's feed/consume loop, whose submission credits guarantee a
    free slab exists whenever a task is pulled — an empty free list at
    ``acquire`` is therefore a logic error, not a wait condition.
    """

    def __init__(self, n_slabs: int, slab_bytes: int):
        self.slab_bytes = int(slab_bytes)
        self._slabs: dict[str, shared_memory.SharedMemory] = {}
        self._free: list[str] = []
        try:
            for _ in range(max(int(n_slabs), 1)):
                seg = shared_memory.SharedMemory(create=True,
                                                 size=self.slab_bytes)
                self._slabs[seg.name] = seg
                self._free.append(seg.name)
        except BaseException:
            self.close()
            raise

    @property
    def n_slabs(self) -> int:
        return len(self._slabs)

    def acquire(self) -> str:
        if not self._free:
            raise RuntimeError(
                "SlabArena exhausted: submission ran ahead of consumption "
                "(credits must bound in-flight tasks by n_slabs)")
        return self._free.pop()

    def release(self, name: str) -> None:
        assert name in self._slabs, f"unknown slab {name!r}"
        self._free.append(name)

    def view(self, name: str) -> memoryview:
        return self._slabs[name].buf

    def close(self) -> None:
        """Unlink every slab; idempotent."""
        for seg in self._slabs.values():
            try:
                seg.close()
                seg.unlink()
            except Exception:
                pass
        self._slabs = {}
        self._free = []


# -- worker side -------------------------------------------------------------

_WORKER_SLABS: dict[str, shared_memory.SharedMemory] = {}


def worker_slab(name: str) -> shared_memory.SharedMemory:
    """Attach (once per worker per slab) and cache: slabs are recycled
    across tasks, so re-attaching per task would waste an mmap each time."""
    seg = _WORKER_SLABS.get(name)
    if seg is None:
        seg = attach(name)
        _WORKER_SLABS[name] = seg
    return seg
