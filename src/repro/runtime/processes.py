"""Multiprocessing backend: profile-shard workers, the single-node MPI analog.

Workers live in separate address spaces, so ``in_process`` is False and
engines must route work through :meth:`map_unordered` /
:meth:`map_throttled` with module-level (picklable) functions; shared state
goes through the pool ``initializer`` (shipped once per worker, not once
per task).

Built on :class:`concurrent.futures.ProcessPoolExecutor` rather than
``multiprocessing.Pool``: a worker that dies abruptly (OOM-kill, segfault,
``SIGKILL`` mid-slab) breaks the pool and every pending future raises
``BrokenProcessPool`` — ``Pool.imap_unordered`` would silently respawn the
worker and hang forever waiting for the lost result.  Ordinary task
exceptions still propagate as themselves (the crash-propagation contract
tested in tests/test_runtime.py).
"""
from __future__ import annotations

import multiprocessing as mp
import os
import sys
from concurrent.futures import (FIRST_COMPLETED, ProcessPoolExecutor,
                                as_completed, wait)
from functools import partial
from typing import Callable, Iterable, Iterator

from repro.runtime.base import Executor, register_executor


_INIT_FAILURE: BaseException | None = None


def _guarded_initializer(initializer: Callable, initargs: tuple) -> None:
    """Capture initializer errors instead of letting the worker die.

    A worker dying during init breaks the whole pool with a generic
    ``BrokenProcessPool``.  Stashing the exception and re-raising it at the
    first task routes the *original* failure through the normal result
    path, where it surfaces with its own type and message."""
    global _INIT_FAILURE
    try:
        initializer(*initargs)
    except BaseException as e:
        _INIT_FAILURE = e


def _call_indexed(fn: Callable, i: int, task) -> tuple[int, object]:
    if _INIT_FAILURE is not None:
        raise _INIT_FAILURE
    return i, fn(task)


@register_executor
class ProcessesExecutor(Executor):
    name = "processes"
    in_process = False

    def __init__(self, n_workers: int = 1, mp_context: str | None = None):
        super().__init__(n_workers)
        if mp_context is None:
            mp_context = os.environ.get("REPRO_MP_CONTEXT") or None
        if mp_context is None:
            # Linux: fork — forkserver/spawn re-import __main__, which hangs
            # the pool in a respawn loop for stdin/interactive programs (no
            # importable main) and re-runs unguarded scripts.  The cost is
            # the classic fork-from-a-threaded-parent hazard (a worker can
            # inherit a mutex locked by e.g. an XLA thread); parents that
            # are thread-heavy can opt out via REPRO_MP_CONTEXT=forkserver.
            # Elsewhere: spawn — macOS fork is unsafe with system frameworks
            # (ObjC/Accelerate state), which is why CPython itself switched
            # the macOS default.  Worker fns and initargs are module-level/
            # picklable, so every start method works.
            methods = mp.get_all_start_methods()
            mp_context = ("fork" if sys.platform == "linux"
                          and "fork" in methods else "spawn")
        self._ctx = mp.get_context(mp_context)

    def parallel_for(self, n_items: int, body: Callable[[int], None]) -> None:
        raise NotImplementedError(
            "the processes executor cannot run closures over shared state; "
            "use map_unordered with a module-level function")

    def _pool(self, n: int, initializer: Callable | None,
              initargs: tuple) -> ProcessPoolExecutor:
        # a fresh pool per call, not a cached one: the initializer contract
        # is per-pool (it must run before any task), and callers batch an
        # entire phase into one map call, so startup amortizes
        guarded = (partial(_guarded_initializer, initializer, initargs)
                   if initializer is not None else None)
        return ProcessPoolExecutor(max_workers=n, mp_context=self._ctx,
                                   initializer=guarded)

    def map_unordered(self, fn: Callable, tasks: Iterable, *,
                      initializer: Callable | None = None,
                      initargs: tuple = ()) -> Iterator[tuple[int, object]]:
        task_list = list(tasks)
        if not task_list:
            return
        pool = self._pool(min(self.n_workers, len(task_list)),
                          initializer, initargs)
        try:
            futs = [pool.submit(_call_indexed, fn, i, t)
                    for i, t in enumerate(task_list)]
            for f in as_completed(futs):
                yield f.result()
        finally:
            # cancel_futures so an aborting caller (or a task exception)
            # doesn't wait out the whole remaining queue
            pool.shutdown(wait=True, cancel_futures=True)

    def map_throttled(self, fn: Callable, tasks: Iterable, *,
                      credits: Callable[[], float],
                      initializer: Callable | None = None,
                      initargs: tuple = (),
                      on_discard: Callable[[object], None] | None = None
                      ) -> Iterator[tuple[int, object]]:
        """Submission-throttled fan-out: task ``i`` is pulled from ``tasks``
        and submitted only while ``i < credits()``.

        ``tasks`` is consumed lazily, so a task source that attaches a
        scarce resource per task (a shared-memory slab) is only asked for a
        task when the credit window guarantees the resource is available.
        ``credits`` must be monotone non-decreasing and is re-read after
        every yielded result, so consumption (which recycles resources)
        extends the window.

        ``on_discard`` receives the result of any task that completed but
        was never yielded (the caller aborted mid-iteration) — the hook for
        releasing external resources a result descriptor may own.
        """
        it = enumerate(iter(tasks))
        pool = self._pool(self.n_workers, initializer, initargs)
        pending: dict = {}
        submitted = 0
        exhausted = False
        try:
            while True:
                while not exhausted and submitted < credits():
                    try:
                        i, task = next(it)
                    except StopIteration:
                        exhausted = True
                        break
                    pending[pool.submit(_call_indexed, fn, i, task)] = i
                    submitted += 1
                if not pending:
                    if exhausted:
                        return
                    raise RuntimeError(
                        "map_throttled stalled: no submission credit and "
                        "nothing in flight — credits() must allow at least "
                        "one task")
                done, _ = wait(pending, return_when=FIRST_COMPLETED)
                for f in done:
                    del pending[f]
                    yield f.result()
        finally:
            pool.shutdown(wait=True, cancel_futures=True)
            if on_discard is not None:
                for f in pending:  # completed but never yielded
                    if f.done() and not f.cancelled() \
                            and f.exception() is None:
                        try:
                            on_discard(f.result())
                        except Exception:
                            pass
