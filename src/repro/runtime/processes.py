"""Multiprocessing backend: profile-shard workers, the single-node MPI analog.

Workers live in separate address spaces, so ``in_process`` is False and
engines must route work through :meth:`map_unordered` with module-level
(picklable) functions; shared state goes through the pool ``initializer``
(shipped once per worker, not once per task).

A worker exception propagates to the parent on the next result iteration —
``imap_unordered`` re-raises the pickled exception and the pool context
manager terminates remaining workers, so failures surface instead of
hanging (the crash-propagation contract tested in tests/test_runtime.py).
"""
from __future__ import annotations

import multiprocessing as mp
import os
import sys
from functools import partial
from typing import Callable, Iterable, Iterator

from repro.runtime.base import Executor, register_executor


_INIT_FAILURE: BaseException | None = None


def _guarded_initializer(initializer: Callable, initargs: tuple) -> None:
    """Capture initializer errors instead of letting the worker die.

    CPython's Pool silently respawns workers that die during init, forever —
    the parent would hang instead of seeing the error.  Stashing the
    exception and re-raising it at the first task routes the failure through
    the normal result path, where ``imap_unordered`` surfaces it."""
    global _INIT_FAILURE
    try:
        initializer(*initargs)
    except BaseException as e:
        _INIT_FAILURE = e


def _call_indexed(fn: Callable, item: tuple[int, object]) -> tuple[int, object]:
    if _INIT_FAILURE is not None:
        raise _INIT_FAILURE
    i, task = item
    return i, fn(task)


@register_executor
class ProcessesExecutor(Executor):
    name = "processes"
    in_process = False

    def __init__(self, n_workers: int = 1, mp_context: str | None = None):
        super().__init__(n_workers)
        if mp_context is None:
            mp_context = os.environ.get("REPRO_MP_CONTEXT") or None
        if mp_context is None:
            # Linux: fork — forkserver/spawn re-import __main__, which hangs
            # the pool in a respawn loop for stdin/interactive programs (no
            # importable main) and re-runs unguarded scripts.  The cost is
            # the classic fork-from-a-threaded-parent hazard (a worker can
            # inherit a mutex locked by e.g. an XLA thread); parents that
            # are thread-heavy can opt out via REPRO_MP_CONTEXT=forkserver.
            # Elsewhere: spawn — macOS fork is unsafe with system frameworks
            # (ObjC/Accelerate state), which is why CPython itself switched
            # the macOS default.  Worker fns and initargs are module-level/
            # picklable, so every start method works.
            methods = mp.get_all_start_methods()
            mp_context = ("fork" if sys.platform == "linux"
                          and "fork" in methods else "spawn")
        self._ctx = mp.get_context(mp_context)

    def parallel_for(self, n_items: int, body: Callable[[int], None]) -> None:
        raise NotImplementedError(
            "the processes executor cannot run closures over shared state; "
            "use map_unordered with a module-level function")

    def map_unordered(self, fn: Callable, tasks: Iterable, *,
                      initializer: Callable | None = None,
                      initargs: tuple = ()) -> Iterator[tuple[int, object]]:
        task_list = list(tasks)
        if not task_list:
            return
        n = min(self.n_workers, len(task_list))
        guarded = (partial(_guarded_initializer, initializer, initargs)
                   if initializer is not None else None)
        # a fresh pool per call, not a cached one: the initializer contract
        # is per-pool (it must run before any task), and callers batch an
        # entire phase into one map_unordered, so startup amortizes
        with self._ctx.Pool(n, initializer=guarded) as pool:
            yield from pool.imap_unordered(
                partial(_call_indexed, fn), list(enumerate(task_list)))
