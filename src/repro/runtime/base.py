"""Executor interface and registry for the aggregation runtime.

The paper's analysis tool gets its order-of-magnitude speedup from *both*
shared-memory threading (§4.2) and distributed-memory ranks (§4.4).  This
package makes the execution substrate of the streaming aggregator a
pluggable choice:

* ``serial``    — inline loop, no concurrency (debugging / baselines);
* ``threads``   — the original shared-counter thread pool (§4.2.4 analog);
* ``processes`` — multiprocessing workers over profile shards, the
  single-node stand-in for the paper's MPI ranks.

An :class:`Executor` exposes two primitives:

* :meth:`Executor.parallel_for` — an in-process parallel loop over item
  indices; the body may close over shared state (threads/serial only);
* :meth:`Executor.map_unordered` — fan out picklable ``fn(task)`` calls and
  yield ``(index, result)`` in completion order; works on every backend and
  is the only primitive the ``processes`` backend supports, since closures
  do not cross address spaces.

Backends self-register via :func:`register_executor`; engines resolve one
with :func:`get_executor` and treat it uniformly.
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from typing import Callable, Iterable, Iterator

_REGISTRY: dict[str, type["Executor"]] = {}


def register_executor(cls: type["Executor"]) -> type["Executor"]:
    """Class decorator: make ``cls`` resolvable by :func:`get_executor`."""
    assert cls.name, "executor classes must set a non-empty `name`"
    _REGISTRY[cls.name] = cls
    return cls


def available_executors() -> list[str]:
    return sorted(_REGISTRY)


def get_executor(name: str, n_workers: int = 1, **kwargs) -> "Executor":
    """Instantiate a registered backend by name.

    Raises ``ValueError`` (not KeyError) on unknown names so config errors
    surface with the list of valid choices.  ``kwargs`` pass through to the
    backend constructor (e.g. ``mp_context`` for ``processes``).
    """
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown executor {name!r}; available: {', '.join(available_executors())}"
        ) from None
    return cls(n_workers, **kwargs)


class Executor(ABC):
    """A unit of parallel execution policy.

    ``in_process`` tells engines whether workers share the caller's address
    space: when False, shared-mutable-state code paths must be replaced by
    shard-local computation plus explicit reduction (see
    :mod:`repro.runtime.reduce`).
    """

    name: str = ""
    in_process: bool = True
    # "stream": the executor runs the streaming engine's loops itself.
    # "ranks": the backend is a whole-run driver (paper §4.4) — the engine
    # delegates the entire aggregation to it instead of calling primitives.
    driver: str = "stream"

    def __init__(self, n_workers: int = 1):
        self.n_workers = max(1, int(n_workers))

    # -- primitives ---------------------------------------------------------
    @abstractmethod
    def parallel_for(self, n_items: int, body: Callable[[int], None]) -> None:
        """Run ``body(i)`` for every ``i in range(n_items)``; the first
        worker exception is re-raised after all workers stop."""

    @abstractmethod
    def map_unordered(self, fn: Callable, tasks: Iterable, *,
                      initializer: Callable | None = None,
                      initargs: tuple = ()) -> Iterator[tuple[int, object]]:
        """Yield ``(index, fn(task))`` pairs in completion order.

        ``fn``/``tasks`` must be picklable for out-of-process backends.
        ``initializer(*initargs)`` runs before any task executes: once per
        worker process on out-of-process backends, once in the caller's
        thread on in-process ones — so it must set up state shared through
        the address space (module globals), not per-thread state."""

    def map_throttled(self, fn: Callable, tasks: Iterable, *,
                      credits: Callable[[], float],
                      initializer: Callable | None = None,
                      initargs: tuple = (),
                      on_discard: Callable[[object], None] | None = None
                      ) -> Iterator[tuple[int, object]]:
        """Like :meth:`map_unordered`, but task ``i`` is pulled from
        ``tasks`` (lazily) and submitted only while ``i < credits()`` —
        the backpressure primitive for feeders that attach a scarce
        per-task resource (shared-memory slabs).  ``on_discard`` disposes
        results that completed but were never yielded to an aborting
        caller.  In-process engines get backpressure from the bounded
        :class:`~repro.runtime.OrderedSink` instead, so only
        out-of-process backends implement this."""
        raise NotImplementedError(
            f"executor {self.name!r} does not support throttled submission")

    # -- helpers ------------------------------------------------------------
    def shards(self, n_items: int) -> list[list[int]]:
        """Deterministic contiguous split of ``range(n_items)`` into at most
        ``n_workers`` non-empty shards (profile-shard layout of paper §4.4)."""
        w = max(1, min(self.n_workers, n_items))
        bounds = [round(k * n_items / w) for k in range(w + 1)]
        return [list(range(bounds[k], bounds[k + 1]))
                for k in range(w) if bounds[k] < bounds[k + 1]]

    def close(self) -> None:
        """Release backend resources (worker pools); idempotent."""

    def __enter__(self) -> "Executor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
