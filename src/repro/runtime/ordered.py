"""In-order result sink: deterministic output from unordered execution.

The PMS two-buffer writer allocates file regions with a fetch-and-add, so
the *byte layout* of the database depends on the order planes are appended.
To make every executor backend produce byte-identical databases (the parity
contract of ``repro.runtime``), workers publish results here tagged with
their item index and a single consumer observes them in strict index order,
regardless of completion order.

No dedicated consumer thread: whichever producer delivers the next-expected
index drains the ready prefix inline (at most one drainer at a time), so
consumption still overlaps remaining computation — the streaming property
of paper §4.3.1 is preserved, only the *order* is pinned.

**Bounded mode** (``window=w``): a producer whose index is ``w`` or more
ahead of the next-expected index blocks until the gap closes.  This caps
the out-of-order buffer at ``w`` items — without it, one slow early item
(profile 0 slowest) leaves O(n_items) encoded planes resident.  Blocking
requires every producer failure to reach :meth:`fail`, otherwise blocked
peers would wait forever; in-process engines wrap worker bodies
accordingly.  A single-producer feeder (the ``processes`` engine's parent
loop) may use a window only if its *submissions* are already credited
against consumption (``Executor.map_throttled`` with ``credits =
consumed + w``): then no delivered index can ever reach ``next + w`` and
``put`` never blocks — with an uncredited feed, blocking would
self-deadlock, since nobody else can deliver the missing index.
"""
from __future__ import annotations

import threading
from typing import Callable


class OrderedSink:
    """Collects ``(index, item)`` pairs and consumes them in index order.

    ``consume(index, item)`` is invoked exactly once per index, in
    ascending order starting at 0, from whichever thread happens to drain.
    A consume exception poisons the sink: it is raised to the draining
    producer and to every later ``put``/``close`` call (no deadlock, no
    silent loss).

    ``window=w`` bounds the out-of-order buffer: ``put(i)`` blocks while
    ``i >= next_expected + w``.  The producer holding ``next_expected`` is
    never blocked, so it always gets through to drain and wake the rest.
    ``max_pending`` records the high-water mark of buffered items.
    """

    def __init__(self, consume: Callable[[int, object], None],
                 window: int | None = None):
        if window is not None and window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._consume = consume
        self._window = window
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: dict[int, object] = {}
        self._next = 0
        self._draining = False
        self._error: BaseException | None = None
        self.max_pending = 0

    def put(self, index: int, item: object) -> None:
        with self._cond:
            if self._window is not None:
                while (self._error is None
                       and index >= self._next + self._window):
                    self._cond.wait()
            if self._error is not None:
                raise self._error
            self._pending[index] = item
            self.max_pending = max(self.max_pending, len(self._pending))
        while True:
            with self._cond:
                if (self._draining or self._error is not None
                        or self._next not in self._pending):
                    return
                self._draining = True
                i = self._next
                current = self._pending.pop(i)
            try:
                self._consume(i, current)
            except BaseException as e:
                with self._cond:
                    self._error = e
                    self._draining = False
                    self._cond.notify_all()
                raise
            with self._cond:
                self._next += 1
                self._draining = False
                self._cond.notify_all()

    def fail(self, exc: BaseException) -> None:
        """Poison the sink from a failing producer.

        Mandatory in bounded mode: producers blocked in :meth:`put` can
        only be released by progress or poison, and a dead producer will
        never deliver the index they are waiting on.
        """
        with self._cond:
            if self._error is None:
                self._error = exc
            self._cond.notify_all()

    @property
    def consumed(self) -> int:
        with self._lock:
            return self._next

    def pending_items(self) -> list:
        """Snapshot of buffered (unconsumed) items — abort-path cleanup for
        feeders whose items carry external resources (shm descriptors)."""
        with self._lock:
            return list(self._pending.values())

    def close(self) -> None:
        """Assert the sink fully drained; re-raise a pending consume error."""
        with self._lock:
            if self._error is not None:
                raise self._error
            if self._pending:
                raise RuntimeError(
                    f"OrderedSink closed with {len(self._pending)} items "
                    f"stranded above index {self._next} (missing index "
                    f"{self._next})")
