"""In-order result sink: deterministic output from unordered execution.

The PMS two-buffer writer allocates file regions with a fetch-and-add, so
the *byte layout* of the database depends on the order planes are appended.
To make every executor backend produce byte-identical databases (the parity
contract of ``repro.runtime``), workers publish results here tagged with
their item index and a single consumer observes them in strict index order,
regardless of completion order.

No dedicated consumer thread: whichever producer delivers the next-expected
index drains the ready prefix inline (at most one drainer at a time), so
consumption still overlaps remaining computation — the streaming property
of paper §4.3.1 is preserved, only the *order* is pinned.
"""
from __future__ import annotations

import threading
from typing import Callable


class OrderedSink:
    """Collects ``(index, item)`` pairs and consumes them in index order.

    ``consume(index, item)`` is invoked exactly once per index, in
    ascending order starting at 0, from whichever thread happens to drain.
    A consume exception poisons the sink: it is raised to the draining
    producer and to every later ``put``/``close`` call (no deadlock, no
    silent loss).
    """

    def __init__(self, consume: Callable[[int, object], None]):
        self._consume = consume
        self._lock = threading.Lock()
        self._pending: dict[int, object] = {}
        self._next = 0
        self._draining = False
        self._error: BaseException | None = None

    def put(self, index: int, item: object) -> None:
        with self._lock:
            if self._error is not None:
                raise self._error
            self._pending[index] = item
        while True:
            with self._lock:
                if (self._draining or self._error is not None
                        or self._next not in self._pending):
                    return
                self._draining = True
                i = self._next
                current = self._pending.pop(i)
            try:
                self._consume(i, current)
            except BaseException as e:
                with self._lock:
                    self._error = e
                    self._draining = False
                raise
            with self._lock:
                self._next += 1
                self._draining = False

    @property
    def consumed(self) -> int:
        with self._lock:
            return self._next

    def close(self) -> None:
        """Assert the sink fully drained; re-raise a pending consume error."""
        with self._lock:
            if self._error is not None:
                raise self._error
            if self._pending:
                raise RuntimeError(
                    f"OrderedSink closed with {len(self._pending)} items "
                    f"stranded above index {self._next} (missing index "
                    f"{self._next})")
