"""Reduction-tree machinery shared by thread, process, and rank engines.

The paper composes parallelism with two-phase reduction trees (§4.4):
phase 1 merges per-worker CCTs, phase 2 merges per-worker statistic
accumulators.  This module holds the generic tree reducer plus the
CCT-with-remaps merge payload, so ``repro.core.aggregate`` (executor
backends) and ``repro.core.reduction`` (the multi-rank driver) share one
implementation instead of each holding a global uniquing lock.
"""
from __future__ import annotations

from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from repro.core.cct import ContextTree


def tree_reduce(items: list, merge, branching: int):
    """Reduce ``items`` with a branching-factor-``branching`` tree.

    ``merge(a, b) -> a`` combines in place.  Returns ``(result, rounds)``;
    rounds == ceil(log_branching(n)) as in the paper's footnote 6.  The
    reduction shape is a pure function of ``(len(items), branching)``, so
    for a fixed item order the result is deterministic — which is what lets
    floating-point statistic merges stay byte-identical across executors.
    """
    assert branching >= 2
    layer = list(items)
    rounds = 0
    while len(layer) > 1:
        nxt = []
        for i in range(0, len(layer), branching):
            head = layer[i]
            for other in layer[i + 1 : i + branching]:
                head = merge(head, other)
            nxt.append(head)
        layer = nxt
        rounds += 1
    return (layer[0] if layer else None), rounds


class StreamingReducer:
    """Deterministic streaming fold with O(log n) items resident.

    A binary-counter carry chain: pushing items 0..n-1 in order merges
    completed sibling pairs immediately, so at most ``log2(n) + 1`` partial
    reductions are live at any time — the streaming replacement for
    materializing all n items and calling :func:`tree_reduce`.  The merge
    shape (and therefore any floating-point op order) is a pure function of
    ``n`` alone, which is the property the executor byte-parity contract
    needs.  ``merge(a, b) -> a`` combines in place with ``a`` the
    earlier-index operand.
    """

    def __init__(self, merge):
        self._merge = merge
        self._slots: list = []  # slot k: a reduction of 2^k items, or None

    def push(self, item) -> None:
        k = 0
        while k < len(self._slots) and self._slots[k] is not None:
            item = self._merge(self._slots[k], item)  # earlier block on the left
            self._slots[k] = None
            k += 1
        if k == len(self._slots):
            self._slots.append(item)
        else:
            self._slots[k] = item

    def result(self):
        """Fold the remaining slots (highest weight = earliest indices first);
        returns None when nothing was pushed."""
        acc = None
        for slot in reversed(self._slots):
            if slot is None:
                continue
            acc = slot if acc is None else self._merge(acc, slot)
        return acc

    def close(self) -> None:
        """No-op; symmetry with :class:`AsyncStreamingReducer` so engines
        can treat either uniformly on abort paths."""


class AsyncStreamingReducer:
    """:class:`StreamingReducer` with the merges executed on a small thread
    pool — same binary-counter carry chain, same shape, same left/right
    operand order, therefore **byte-identical results**; only *where* each
    merge runs changes.

    This unclogs the known sharded phase-2 bottleneck (ROADMAP item 3): the
    parent's consume thread used to execute every statistics merge inline
    between slab recycles, serializing O(n log n) merge work behind the
    writer.  Here :meth:`push` only links futures (O(log n) bookkeeping)
    and returns; pool threads do the merges, overlapping worker compute and
    writer IO.  numpy releases the GIL inside the sort/reduceat kernels, so
    the overlap is real even in-process.

    Deadlock-freedom for any pool size >= 1: leaves arrive pre-resolved and
    every merge depends only on futures submitted strictly earlier, so FIFO
    pool order always finds runnable work.  A merge that raises parks the
    exception in its future; dependents re-raise it, and :meth:`result`
    surfaces the original error.
    """

    def __init__(self, merge, n_threads: int = 2):
        self._merge = merge
        self._pool = ThreadPoolExecutor(max_workers=max(1, int(n_threads)),
                                        thread_name_prefix="carry-merge")
        self._slots: list[Future | None] = []
        self._closed = False

    def push(self, item) -> None:
        fut: Future = Future()
        fut.set_result(item)
        k = 0
        while k < len(self._slots) and self._slots[k] is not None:
            left = self._slots[k]
            fut = self._pool.submit(
                lambda a=left, b=fut: self._merge(a.result(), b.result()))
            self._slots[k] = None
            k += 1
        if k == len(self._slots):
            self._slots.append(fut)
        else:
            self._slots[k] = fut

    def result(self):
        """Drain the chain: fold remaining slots exactly like
        :meth:`StreamingReducer.result`, then release the pool."""
        try:
            acc = None
            for slot in reversed(self._slots):
                if slot is None:
                    continue
                item = slot.result()
                acc = item if acc is None else self._merge(acc, item)
            return acc
        finally:
            self.close()

    def close(self) -> None:
        """Release pool threads; in-flight merges finish on their own (pure
        compute, no external resources), we just stop waiting for them —
        the abort-path teardown must never hang on statistics."""
        if not self._closed:
            self._closed = True
            self._pool.shutdown(wait=False)


@dataclass
class TreeWithMaps:
    """A CCT plus, per contributing shard/rank, the remap of its local ids."""

    tree: ContextTree
    maps: dict[int, np.ndarray]


def merge_tree_with_maps(a: TreeWithMaps, b: TreeWithMaps) -> TreeWithMaps:
    """Phase-1 merge payload: unify ``b`` into ``a``, composing id remaps."""
    remap = a.tree.merge(b.tree)
    for key, m in b.maps.items():
        a.maps[key] = remap[m]
    return a
