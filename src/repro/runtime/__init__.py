"""Pluggable execution backends for the aggregation engine.

See :mod:`repro.runtime.base` for the interface contract.  Importing this
package registers the four built-in backends: ``serial``, ``threads``,
``processes``, and the whole-run ``ranks`` driver.
"""
from repro.runtime.base import (Executor, available_executors, get_executor,
                                register_executor)
from repro.runtime.ordered import OrderedSink
from repro.runtime.reduce import TreeWithMaps, merge_tree_with_maps, tree_reduce
from repro.runtime.serial import SerialExecutor
from repro.runtime.shm import SlabArena
from repro.runtime.threads import ThreadsExecutor, parallel_for
from repro.runtime.processes import ProcessesExecutor
from repro.runtime.ranks import RanksExecutor

__all__ = [
    "Executor", "available_executors", "get_executor", "register_executor",
    "OrderedSink", "SlabArena", "TreeWithMaps", "merge_tree_with_maps",
    "tree_reduce", "SerialExecutor", "ThreadsExecutor", "ProcessesExecutor",
    "RanksExecutor", "parallel_for",
]
