"""The multi-rank reduction driver as a registered executor backend.

``AggregationConfig(executor="ranks")`` selects the paper's §4.4 MPI-analog
driver (``repro.core.reduction.aggregate_multiprocess``) through the same
registry as the streaming backends: ``n_workers`` becomes the rank count
and the legacy ``n_threads`` knob the threads-per-rank.  The engine
recognizes the backend via ``driver == "ranks"`` and hands the whole run to
the rank driver instead of the streaming loop, so CLI/config surfaces need
no special-casing.

The rank driver writes its PMS planes in per-rank segments (strided profile
interleave), so its databases are byte-*layout* different from the
streaming backends' — but semantically identical: every query result
(plane contents, stripes, statistics, top-k, diffs) matches, which is the
contract ``tests/test_query.py`` pins down.

``parallel_for``/``map_unordered`` are inherited from the ``processes``
pool so the backend is still usable as a generic executor (e.g. by
``build_cms``), not only as a whole-run driver.
"""
from __future__ import annotations

from repro.runtime.base import register_executor
from repro.runtime.processes import ProcessesExecutor


@register_executor
class RanksExecutor(ProcessesExecutor):
    name = "ranks"
    in_process = False
    driver = "ranks"
