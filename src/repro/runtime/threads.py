"""Shared-memory thread backend (paper §4.2.4's custom task runtime analog).

Hosts :func:`parallel_for`, extracted from ``repro.core.aggregate``: workers
pull indices from a shared counter, so load imbalance between items
self-schedules without a queue per item.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator

from repro.runtime.base import Executor, register_executor


def parallel_for(n_items: int, n_threads: int, body: Callable[[int], None]) -> None:
    """Non-blocking parallel loop over items: workers pull indices from a
    shared counter; the first body exception stops the pool and re-raises."""
    counter = iter(range(n_items))
    lock = threading.Lock()
    errors: list[BaseException] = []

    def work():
        while True:
            with lock:
                # stop pulling new indices once any worker failed: a late
                # failure must not drain (and buffer) the whole remaining run
                if errors:
                    return
                i = next(counter, None)
            if i is None:
                return
            try:
                body(i)
            except BaseException as e:
                errors.append(e)
                return

    threads = [threading.Thread(target=work)
               for _ in range(min(n_threads, max(n_items, 1)))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


@register_executor
class ThreadsExecutor(Executor):
    name = "threads"
    in_process = True

    def parallel_for(self, n_items: int, body: Callable[[int], None]) -> None:
        parallel_for(n_items, self.n_workers, body)

    def map_unordered(self, fn: Callable, tasks: Iterable, *,
                      initializer: Callable | None = None,
                      initargs: tuple = ()) -> Iterator[tuple[int, object]]:
        task_list = list(tasks)
        if not task_list:
            return
        if initializer is not None:
            initializer(*initargs)  # threads share the address space: run once
        results: queue.Queue = queue.Queue()
        errors: list[BaseException] = []

        def runner():
            try:
                parallel_for(len(task_list), self.n_workers,
                             lambda i: results.put((i, fn(task_list[i]))))
            except BaseException as e:
                errors.append(e)
            finally:
                results.put(None)  # sentinel: all workers joined

        t = threading.Thread(target=runner)
        t.start()
        try:
            while True:
                item = results.get()
                if item is None:
                    break
                yield item
        finally:
            t.join()
        if errors:
            raise errors[0]
