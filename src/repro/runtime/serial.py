"""Inline executor: no concurrency, exact same engine semantics.

The byte-identity contract of the aggregation engine (serial == threads ==
processes output) makes this backend the debugging oracle: any divergence
observed under a concurrent backend can be bisected against the serial run.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator

from repro.runtime.base import Executor, register_executor


@register_executor
class SerialExecutor(Executor):
    name = "serial"
    in_process = True

    def parallel_for(self, n_items: int, body: Callable[[int], None]) -> None:
        for i in range(n_items):
            body(i)

    def map_unordered(self, fn: Callable, tasks: Iterable, *,
                      initializer: Callable | None = None,
                      initargs: tuple = ()) -> Iterator[tuple[int, object]]:
        if initializer is not None:
            initializer(*initargs)
        for i, task in enumerate(tasks):
            yield i, fn(task)
