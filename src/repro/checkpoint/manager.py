"""Fault-tolerant checkpointing.

* **atomic**: a step is written into ``step_N.tmp`` and renamed to
  ``step_N`` only when complete; a crash mid-write can never corrupt the
  restore point (torn directories are garbage-collected on restore);
* **async**: saves run on a background thread (double-buffered against the
  training loop — the paper's two-buffer overlap, applied to checkpoints);
* **elastic**: arrays are stored unsharded (numpy) with pytree paths, so a
  job may restore onto a *different* mesh — the caller re-applies
  shardings derived from logical rules, not device counts.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

import jax


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{i}/")
    else:
        yield prefix[:-1], tree


def _unflatten(flat: dict):
    """Rebuild nested dict/tuple structure from path keys."""
    root: dict = {}
    for path, val in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.isdigit() for k in keys):
            return tuple(fix(node[str(i)]) for i in range(len(keys)))
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = str(directory)
        os.makedirs(self.dir, exist_ok=True)
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending = None
        self._lock = threading.Lock()

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: dict, extra_meta: dict | None = None):
        """state: pytree of arrays (params/opt/data cursors)."""
        host = {k: np.asarray(v) for k, v in _flatten(state)}
        if self._pool is None:
            self._write(step, host, extra_meta or {})
            return None
        with self._lock:
            if self._pending is not None:
                self._pending.result()  # backpressure: one save in flight
            self._pending = self._pool.submit(self._write, step, host,
                                              extra_meta or {})
        return self._pending

    def _write(self, step: int, host: dict, meta: dict):
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(host), **meta}, f)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    # -- restore ----------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
            elif name.endswith(".tmp"):  # torn write: discard
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)
        return sorted(out)

    def restore(self, step: int | None = None, *, shardings=None):
        steps = self.list_steps()
        if not steps:
            return None, None
        step = steps[-1] if step is None else step
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return step, tree
