from repro.configs.base import (ARCH_REGISTRY, ModelConfig, ShapeConfig,
                                SHAPES, get_arch, reduced, register_arch)

__all__ = ["ARCH_REGISTRY", "ModelConfig", "ShapeConfig", "SHAPES",
           "get_arch", "reduced", "register_arch"]
