"""whisper-small: enc-dec, conv frontend stub [arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, encoder_layers=12,
    max_decoder_len=448, act="gelu",
))
