"""xlstm-350m: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
24 blocks, an sLSTM block every 4th (18 mLSTM + 6 sLSTM); d_ff=0 per the
assignment — blocks carry their internal up/down projections only."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    ssm_expand=2, slstm_every=4,
))
