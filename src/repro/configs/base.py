"""Config system: model configs, shape configs, the architecture registry."""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    act: str = "silu"           # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert ff width (0 -> d_ff)
    capacity_factor: float = 1.25
    # vision (vlm): interleaved gated cross-attention layers
    cross_attn_every: int = 0
    vision_tokens: int = 0
    # audio (enc-dec)
    encoder_layers: int = 0
    max_decoder_len: int = 448
    # ssm / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0          # 0 -> d_inner // 64
    attn_every: int = 0         # hybrid: shared attn block every k ssm layers
    slstm_every: int = 0        # xlstm: sLSTM block every k blocks
    # execution knobs (hillclimb levers — not architecture)
    moe_dispatch: str = "sorted"   # "sorted" (global) | "rowwise" (local)
    dtype: str = "bfloat16"
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 1024
    ssm_chunk: int = 256
    causal_mode: str = "masked"   # "masked" | "triangle" (skip future kv blocks)
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(self.d_inner // 64, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_REGISTRY: dict[str, "ModelConfig"] = {}

_ARCH_MODULES = [
    "yi_6b", "codeqwen1_5_7b", "gemma_7b", "qwen3_0_6b", "grok_1_314b",
    "qwen3_moe_30b_a3b", "llama_3_2_vision_11b", "whisper_small",
    "zamba2_7b", "xlstm_350m",
]


def register_arch(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if not ARCH_REGISTRY:
        load_all()
    return ARCH_REGISTRY[name]


def load_all() -> dict[str, ModelConfig]:
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    return ARCH_REGISTRY


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return cfg.replace(
        n_layers=min(cfg.n_layers, 2 if not cfg.attn_every else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256,
        head_dim=32 if cfg.head_dim else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        capacity_factor=4.0,  # dropless at smoke scale: decode == prefill

        vision_tokens=16 if cfg.vision_tokens else 0,
        cross_attn_every=min(cfg.cross_attn_every, 2),
        encoder_layers=min(cfg.encoder_layers, 2),
        max_decoder_len=32 if cfg.encoder_layers else cfg.max_decoder_len,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_heads=2 if cfg.ssm_state else 0,
        attn_every=min(cfg.attn_every, 2),
        slstm_every=cfg.slstm_every,
        q_chunk=16, kv_chunk=16, ssm_chunk=8,
        dtype="float32", remat=False,
    )
