"""zamba2-7b: Mamba2 backbone + shared attention block
[arXiv:2411.15242; unverified].  81 Mamba2 layers; ONE shared
attention+MLP transformer block applied before every 6-layer group
(14 applications, shared parameters, per-application KV caches)."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, attn_every=6,
))
