"""llama-3.2-vision-11b: cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  Vision frontend is a
stub: input_specs() supplies precomputed patch embeddings (1600 tokens,
rounded from 1601 for chunk divisibility — see DESIGN.md)."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=5e5,
    cross_attn_every=5, vision_tokens=1600,
))
