"""gemma-7b: GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab_size=256000, head_dim=256, act="gelu",
    tie_embeddings=True,
))
