"""qwen3-moe-30b-a3b: MoE 128 experts top-8, per-expert ff 768
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128, qk_norm=True,
    n_experts=128, top_k=8, moe_d_ff=768, capacity_factor=1.25,
    rope_theta=1e6,
))
