"""qwen3-0.6b: qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6, tie_embeddings=True,
))
