"""grok-1-314b: MoE 8 experts top-2 [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    n_experts=8, top_k=2, capacity_factor=1.25,
))
