"""The in-job measurement subsystem (the paper's altered HPCToolkit side).

One :class:`Profiler` per worker (host process / device stream analog)
accumulates *exclusive* sparse metrics onto a program-structure CCT:

* host contexts (``data``, ``dispatch``, ``checkpoint``) carry host-side
  step metrics — the CPU-metric analog;
* device contexts (from HLO attribution of the compiled step) carry
  device-side metrics (bytes moved, op counts, est. compute/collective
  shares) — the GPU-metric analog (natural cross-metric sparsity).

``finish()`` writes the per-worker profile file in the paper's sparse
measurement format plus a sample trace; the post-mortem streaming
aggregation engine (repro.core.aggregate) consumes these directly.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.cct import (KIND_MODULE, KIND_OP, KIND_PHASE,
                            ContextTree)
from repro.core.metrics import default_registry
from repro.core.sparse import MeasurementProfile, SparseMetrics, Trace
from repro.profiling import hlo_attrib


class Profiler:
    def __init__(self, identity: dict, *, families=("attention", "dense"),
                 trace: bool = True):
        self.identity = dict(identity)
        self.registry = default_registry(families=families)
        self.tree = ContextTree()
        self._acc: dict[tuple[int, int], float] = {}
        self._trace_t: list[float] = []
        self._trace_c: list[int] = []
        self._trace_on = trace
        self._t0 = time.perf_counter()
        self._structures: list[str] = []
        # host phase contexts
        self._phase = {
            name: self.tree.child(0, KIND_PHASE, name)
            for name in ("train", "data", "dispatch", "checkpoint")
        }

    # -- accumulation -----------------------------------------------------------
    def add(self, ctx: int, metric: str, value: float) -> None:
        if value == 0.0:
            return
        mid = self.registry[metric].mid if metric in self.registry else \
            self.registry.register(metric).mid
        key = (ctx, mid)
        self._acc[key] = self._acc.get(key, 0.0) + float(value)

    def sample(self, ctx: int) -> None:
        if self._trace_on:
            self._trace_t.append(time.perf_counter() - self._t0)
            self._trace_c.append(ctx)

    # -- hooks --------------------------------------------------------------------
    def on_step(self, rec: dict) -> None:
        """Trainer hook: host-side metrics on host contexts."""
        t = self._phase["train"]
        self.add(t, "host.step_time", rec.get("step_time", 0.0))
        self.add(self._phase["data"], "host.data_wait", rec.get("data_wait", 0.0))
        self.sample(t)

    def attribute_compiled(self, hlo_text: str, *, binary: str = "step",
                           measured: dict | None = None,
                           struct_dir: str | None = None) -> None:
        """Attribute compiled-module costs to op contexts under train/.

        ``measured`` may carry module totals (flops, bytes) from
        ``cost_analysis`` — distributed over ops by output bytes.
        """
        agg = hlo_attrib.attribute(hlo_text)
        total_bytes = sum(v["bytes"] for v in agg.values()) or 1.0
        flops_total = (measured or {}).get("flops", 0.0)
        parent = self._phase["train"]
        for scope, vals in agg.items():
            path = hlo_attrib.scope_to_path(scope)
            leaf = scope.split("/")[-1] if scope else "op"
            node = self.tree.path(path + [(KIND_OP, leaf)], parent)
            self.add(node, "dev.bytes_hbm", vals["bytes"])
            self.add(node, "dev.occupancy", vals["count"])
            self.add(node, "dev.bytes_ici", vals.get("collective", 0.0))
            if flops_total:
                self.add(node, "dev.flops",
                         flops_total * vals["bytes"] / total_bytes)
        if struct_dir is not None:
            os.makedirs(struct_dir, exist_ok=True)
            s = hlo_attrib.build_structure(hlo_text, binary)
            path = os.path.join(struct_dir, f"{binary}.struct.json")
            s.save(path)
            self._structures.append(path)

    def module_metric(self, module_path: list[str], metric: str,
                      value: float) -> None:
        """Attribute a value to an explicit module path under train/."""
        parts = [(KIND_MODULE, p) for p in module_path]
        node = self.tree.path(parts, self._phase["train"])
        self.add(node, metric, value)
        self.sample(node)

    # -- completion ------------------------------------------------------------
    def finish(self, path) -> MeasurementProfile:
        ctxs = np.array([k[0] for k in self._acc], dtype=np.int64)
        mids = np.array([k[1] for k in self._acc], dtype=np.int64)
        vals = np.array(list(self._acc.values()), dtype=np.float64)
        prof = MeasurementProfile(
            environment={"app": "repro", "registry": self.registry.to_json()},
            identity=self.identity,
            file_paths=list(self._structures),
            tree=self.tree,
            trace=Trace(np.asarray(self._trace_t, np.float64),
                        np.asarray(self._trace_c, np.uint32)),
            metrics=SparseMetrics.from_triplets(ctxs, mids, vals),
        )
        prof.save(path)
        return prof
