"""HLO cost attribution — the "binary analysis" of this framework.

HPCToolkit attributes instruction offsets to lexical scopes parsed from
DWARF; our measured artifact is a compiled XLA module, whose instruction
metadata (``op_name="jit(f)/while/body/dot_general..."``) plays the role
of line/loop/inline info.  This module parses the (lowered or compiled)
HLO text into:

* per-op attribution records (opcode, scope path, output bytes, est. flops)
  used by the in-job profiler to emit device metrics per context;
* a :class:`repro.core.lexical.StructureInfo` "structure file": fusion ops
  whose fused computations contain instructions from *several* scopes get
  multiple weighted routes — exactly the flat-GPU-sample provenance problem
  §4.1.3 reconstructs.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass

from repro.core.cct import KIND_LOOP, KIND_MODULE
from repro.core.lexical import StructureInfo

_SHAPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*?)\)(.*)$")
_META_RE = re.compile(r'op_name="([^"]*)"')
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _SHAPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _SHAPE_BYTES[dt]
    return total


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")


@dataclass
class OpRecord:
    name: str
    opcode: str
    scope: str          # op_name metadata path
    out_bytes: int
    weight: float = 1.0
    calls: str = ""     # fusion -> fused computation name


def parse_hlo(hlo_text: str) -> list[OpRecord]:
    """Every instruction in every computation, with scope metadata."""
    out = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, opcode, _args, rest = m.groups()
        if opcode in ("parameter", "constant", "tuple", "get-tuple-element"):
            continue
        meta = _META_RE.search(rest)
        scope = meta.group(1) if meta else ""
        calls = ""
        if opcode == "fusion":
            cm = _CALLS_RE.search(rest)
            calls = cm.group(1) if cm else ""
        out.append(OpRecord(name, opcode, scope, shape_bytes(shape), 1.0, calls))
    return out


def scope_to_path(scope: str) -> list[tuple[int, str]]:
    """'jit(step)/while/body/.../dot_general' -> lexical path parts."""
    parts = [p for p in scope.split("/") if p]
    path = []
    for p in parts[:-1]:
        kind = KIND_LOOP if p in ("while", "body", "cond", "scan", "remat",
                                  "checkpoint") else KIND_MODULE
        path.append((kind, p))
    return path


def attribute(hlo_text: str) -> dict[str, dict]:
    """Aggregate per-leaf-scope costs: bytes moved, op counts by class."""
    recs = parse_hlo(hlo_text)
    agg: dict[str, dict] = defaultdict(lambda: defaultdict(float))
    for r in recs:
        leaf = r.scope.split("/")[-1] if r.scope else r.opcode
        key = r.scope or r.opcode
        agg[key]["bytes"] += r.out_bytes
        agg[key]["count"] += 1
        cls = ("collective" if r.opcode.startswith(("all-", "collective",
                                                    "reduce-scatter"))
               else "dot" if r.opcode in ("dot", "convolution", "fusion")
               else "other")
        agg[key][cls] += r.out_bytes
    return dict(agg)


def build_structure(hlo_text: str, binary_name: str) -> StructureInfo:
    """Structure file with multi-route fusion reconstruction (§4.1.3).

    Fusions appear as a caller op plus a fused computation whose inner
    instructions carry their original scopes; when the inner scopes span
    several modules the fusion gets one weighted route per module.
    """
    s = StructureInfo(binary_name)
    # pass 1: scopes of the instructions inside each (fused) computation
    comp = None
    comp_scopes: dict[str, list[str]] = defaultdict(list)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m_head = re.match(r"^%?([\w.\-]+)\s*\(.*\)\s*->.*\{$", stripped)
        if m_head:
            comp = m_head.group(1)
            continue
        if stripped == "}":
            comp = None
            continue
        m = _INSTR_RE.match(line)
        if m and comp:
            meta = _META_RE.search(m.group(5))
            if meta and meta.group(1):
                comp_scopes[comp].append(meta.group(1))
    # pass 2: route table; fusions spanning several modules get multi-routes
    for rec in parse_hlo(hlo_text):
        if not rec.scope:
            continue
        if rec.opcode == "fusion" and rec.calls:
            inner = comp_scopes.get(rec.calls, [])
            mods = defaultdict(int)
            for sc in inner:
                mods["/".join(sc.split("/")[:-1])] += 1
            if len(mods) > 1:
                total = sum(mods.values())
                for mod, cnt in sorted(mods.items()):
                    s.add_op(rec.name, scope_to_path(mod + "/x"),
                             weight=cnt / total)
                continue
        s.add_op(rec.name, scope_to_path(rec.scope))
    return s
