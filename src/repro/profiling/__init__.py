from repro.profiling.instrument import Profiler

__all__ = ["Profiler"]
