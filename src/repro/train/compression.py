"""Gradient compression for the slow cross-pod hop (DESIGN.md §8).

Two error-feedback schemes:

* **top-k** — keep the k largest-magnitude entries per tensor, accumulate
  the remainder into a residual that is re-injected next step;
* **block int8** — the Pallas ``int8_quant`` kernel (block-scaled symmetric
  quantization), residual = quantization error.

``compressed_psum_pod`` is the collective-schedule variant: inside a
``shard_map`` over the ``pod`` axis, gradients are quantized to int8,
all-gathered across pods (4x fewer bytes on the wire than an f32
all-reduce — this is what moves the §Roofline collective term), and
dequant-averaged locally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


# -- error-feedback top-k ------------------------------------------------------

def topk_compress(g: jax.Array, frac: float, residual: jax.Array):
    """Returns ((idx, vals, n), new_residual); g and residual flat f32."""
    g = g + residual
    n = g.shape[0]
    k = max(int(n * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(g), k)
    picked = g[idx]
    dense = jnp.zeros_like(g).at[idx].set(picked)
    return (idx, picked, n), g - dense


def topk_decompress(payload, n: int):
    idx, vals, _ = payload
    return jnp.zeros(n, vals.dtype).at[idx].set(vals)


# -- error-feedback int8 -------------------------------------------------------

def int8_compress(g: jax.Array, residual: jax.Array):
    q, scales, err = kops.int8_quant(g + residual)
    return (q, scales), err


def int8_decompress(payload, n: int):
    q, scales = payload
    return kops.int8_dequant(q, scales, n)


# -- compressed cross-pod all-reduce ------------------------------------------

def compressed_psum_pod(x: jax.Array, mesh, *, axis: str = "pod"):
    """Mean over the pod axis with int8 on the wire.

    Must be called inside shard_map-partitioned code, or applied to a
    full tensor via the wrapper below.  Wire bytes: n*(1B q + 4B/block
    scale) vs 4B/elem for f32 psum.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    npods = mesh.shape[axis]

    def body(xl):
        flat = xl.reshape(-1)
        pad = (-flat.shape[0]) % 2048
        flat = jnp.pad(flat, (0, pad))
        amax = jnp.max(jnp.abs(flat.reshape(-1, 2048)), axis=1)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(flat.reshape(-1, 2048) / scale[:, None]),
                     -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, axis)          # int8 on the wire
        sg = jax.lax.all_gather(scale, axis)
        deq = (qg.astype(jnp.float32) * sg[..., None]).sum(axis=0) / npods
        return deq.reshape(-1)[: xl.size].reshape(xl.shape)

    return shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(x)
