"""Sharded AdamW (from scratch — no external optimizer dependency).

Moments are f32 and inherit the parameter PartitionSpecs, so under
FSDP/ZeRO rules the optimizer state is sharded exactly like the weights.
Global-norm clipping runs in f32.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs):
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs, "v": param_specs, "step": P()}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mdt = m.dtype  # moments may be bf16 for the largest models
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
