"""Training loop: grad accumulation, straggler watchdog, checkpoint hooks,
profiler integration.

The jitted step closes over the sharding rules at trace time (logical
constraints in model code resolve against the active mesh), so the same
model code runs single-host smoke tests and 512-chip dry-runs unchanged.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.specs import set_rules
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass
class TrainerConfig:
    steps: int = 100
    microbatches: int = 1
    log_every: int = 10
    ckpt_every: int = 50
    deadline_s: float = 0.0      # 0 = watchdog off
    max_retries: int = 1


def make_train_step(model, opt_cfg: AdamWConfig, *, mesh=None, rules=None,
                    microbatches: int = 1, accum_dtype=jnp.float32):
    """Build the (jittable) train step: loss -> grads -> AdamW update.

    With ``microbatches > 1`` the batch is split and gradients accumulate
    under ``lax.scan`` — per-microbatch gradient reductions overlap the
    next microbatch's compute (the XLA scheduler interleaves them), which
    is the compute/comm-overlap lever from DESIGN.md §8.
    """

    def train_step(params, opt_state, batch):
        ctx = set_rules(mesh, rules) if mesh is not None else contextlib.nullcontext()
        with ctx:
            if microbatches > 1:
                def split(x):
                    return x.reshape((microbatches, x.shape[0] // microbatches)
                                     + x.shape[1:])
                mb = jax.tree_util.tree_map(split, batch)

                # NOTE (§Perf, refuted hypothesis): accumulating inside the
                # differentiated function (grad of a loss-scan) was tried to
                # defer the data-axis gradient psum to once per step; GSPMD
                # did NOT defer it and the extra rematerialization raised
                # both memory and collective terms ~35% — the explicit
                # accumulator below lowers better.
                def body(acc, b):
                    l, g = jax.value_and_grad(model.loss_fn)(params, b)
                    acc_l, acc_g = acc
                    return (acc_l + l,
                            jax.tree_util.tree_map(jnp.add, acc_g, g)), None

                zero_g = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, accum_dtype), params)
                (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), mb)
                loss = loss / microbatches
                grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            else:
                loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            params2, opt2, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


class Trainer:
    """Drives the jitted step over a pipeline with fault-tolerance hooks."""

    def __init__(self, model, opt_cfg: AdamWConfig, tcfg: TrainerConfig,
                 pipeline, *, ckpt=None, profiler=None, mesh=None, rules=None):
        self.model = model
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.profiler = profiler
        self.step_fn = jax.jit(make_train_step(
            model, opt_cfg, mesh=mesh, rules=rules,
            microbatches=tcfg.microbatches))
        self.straggler_events: list[dict] = []
        self.history: list[dict] = []

    def init_state(self, seed: int = 0, dtype=jnp.float32):
        from repro.models import params as P
        params = P.init_params(self.model.param_defs(), seed, dtype)
        return params, init_opt_state(params)

    def run(self, params, opt_state, *, start_step: int = 0,
            steps: int | None = None):
        steps = steps if steps is not None else self.tcfg.steps
        for step in range(start_step, start_step + steps):
            t_data = time.perf_counter()
            batch = {"tokens": jnp.asarray(self.pipeline.batch_at(step))}
            data_wait = time.perf_counter() - t_data

            t0 = time.perf_counter()
            tries = 0
            while True:
                try:
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch)
                    jax.block_until_ready(metrics["loss"])
                    break
                except Exception:
                    tries += 1
                    if tries > self.tcfg.max_retries:
                        raise
            dt = time.perf_counter() - t0

            if self.tcfg.deadline_s and dt > self.tcfg.deadline_s:
                # straggler mitigation: record, ask the pipeline to rebalance
                self.straggler_events.append({"step": step, "dt": dt})
                if hasattr(self.pipeline, "delay_s"):
                    self.pipeline.delay_s = 0.0  # drop the slow path

            rec = {"step": step, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "step_time": dt, "data_wait": data_wait}
            self.history.append(rec)
            if self.profiler is not None:
                self.profiler.on_step(rec)
            if self.ckpt is not None and (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step + 1, {
                    "params": params, "opt": opt_state,
                    "data": {"step": np.int64(step + 1)},
                })
        if self.ckpt is not None:
            self.ckpt.wait()
        return params, opt_state
