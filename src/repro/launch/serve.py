"""Serving launcher: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 6 --prompt-len 16 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.base import get_arch, reduced
from repro.models import params as PD
from repro.models.api import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    import jax.numpy as jnp
    params = PD.init_params(model.param_defs(), 0, jnp.float32)
    eng = ServeEngine(model, params,
                      max_len=args.prompt_len + args.new_tokens + 1,
                      max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size,
                                 args.prompt_len).astype(np.int32),
                    args.new_tokens) for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = eng.serve(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[:3]):
        print(f"req{i}: {o.tolist()}")


if __name__ == "__main__":
    main()
