"""Serving launcher: batched generation, and the query service over HTTP.

Batched LLM generation (the original mode)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 6 --prompt-len 16 --new-tokens 8

Query service over a completed analysis database::

    PYTHONPATH=src python -m repro.launch.serve query-server runs/db \
        --port 8422 --max-batch 16 --max-wait-ms 2 --max-queue 256 \
        --cache-mb 64 [--warm-mb 32 | --no-warm] [--no-batching] \
        [--shards 4]

The query server prints one JSON line with its URL and warming report,
then blocks until SIGINT.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _query_server_main(argv):
    from repro.query import Database
    from repro.serve.http import QueryHTTPServer

    ap = argparse.ArgumentParser(prog="repro.launch.serve query-server")
    ap.add_argument("db", help="database directory (db.pms [+ db.cms/db.trc])")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8422,
                    help="0 picks a free port (printed on startup)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="micro-batch window size cap")
    ap.add_argument("--max-wait-ms", type=float, default=0.0,
                    help="max stall collecting a window after its first "
                         "request arrives (default 0: opportunistic — "
                         "serve what is queued, never stall an idle "
                         "worker; small positive values trade latency "
                         "for fuller windows under sparse bursty traffic)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission queue bound (per shard when sharded); "
                         "overflow answers 429")
    ap.add_argument("--shards", type=int, default=0,
                    help="N > 0 serves from N worker processes (one "
                         "Database + plane cache each, consistent-hash "
                         "routed by plane, supervisor respawns dead "
                         "workers); 0 = single-process")
    ap.add_argument("--shard-slab-mb", type=int, default=4,
                    help="shm slab size for sharded plane payloads")
    ap.add_argument("--no-adaptive-wait", action="store_true",
                    help="always hold batch windows for --max-wait-ms "
                         "instead of flushing when a worker idles")
    ap.add_argument("--workers", type=int, default=4,
                    help="window-serving workers on the runtime executor")
    ap.add_argument("--executor", default="threads",
                    choices=["threads", "serial"],
                    help="runtime backend for the serving loops")
    ap.add_argument("--cache-mb", type=int, default=64,
                    help="decoded-plane LRU budget")
    ap.add_argument("--warm-mb", type=int, default=None,
                    help="startup warming budget (default: 90%% of cache)")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip startup cache warming")
    ap.add_argument("--no-batching", action="store_true",
                    help="serve each HTTP call directly (baseline mode)")
    ap.add_argument("--timeout-s", type=float, default=30.0,
                    help="default per-request deadline")
    args = ap.parse_args(argv)

    warm_bytes = (0 if args.no_warm
                  else None if args.warm_mb is None else args.warm_mb << 20)
    with Database(args.db, cache_bytes=args.cache_mb << 20) as db, \
            QueryHTTPServer(db, host=args.host, port=args.port,
                            batching=not args.no_batching,
                            max_batch=args.max_batch,
                            max_wait_ms=args.max_wait_ms,
                            max_queue=args.max_queue,
                            executor=args.executor, n_workers=args.workers,
                            default_timeout_s=args.timeout_s,
                            adaptive_wait=not args.no_adaptive_wait,
                            warm_bytes=warm_bytes, shards=args.shards,
                            shard_slab_bytes=args.shard_slab_mb << 20) as srv:
        print(json.dumps({"url": srv.url, "batching": srv.batching,
                          "shards": srv.shards,
                          "profiles": db.n_profiles,
                          "contexts": db.n_contexts,
                          "warm": srv.warm_report}), flush=True)
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            print("shutting down", file=sys.stderr)


def _generate_main(argv):
    from repro.configs.base import get_arch, reduced
    from repro.models import params as PD
    from repro.models.api import build_model
    from repro.serve.engine import Request, ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    import jax.numpy as jnp
    params = PD.init_params(model.param_defs(), 0, jnp.float32)
    eng = ServeEngine(model, params,
                      max_len=args.prompt_len + args.new_tokens + 1,
                      max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size,
                                 args.prompt_len).astype(np.int32),
                    args.new_tokens) for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = eng.serve(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[:3]):
        print(f"req{i}: {o.tolist()}")


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "query-server":
        _query_server_main(argv[1:])
    else:
        _generate_main(argv)


if __name__ == "__main__":
    main()
