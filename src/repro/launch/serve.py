"""Serving launcher: batched generation, and the query service over HTTP.

Batched LLM generation (the original mode)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 6 --prompt-len 16 --new-tokens 8

Query service over a completed analysis database::

    PYTHONPATH=src python -m repro.launch.serve query-server runs/db \
        --port 8422 --max-batch 16 --max-wait-ms 2 --max-queue 256 \
        --cache-mb 64 [--warm-mb 32 | --no-warm] [--no-batching] \
        [--shards 4]

Query service *following* a live snapshot root (``db`` is the ingest
tier's output directory; the server picks up each published epoch without
restart)::

    PYTHONPATH=src python -m repro.launch.serve query-server runs/live \
        --follow [--poll-ms 250] [--shards 4]

Multi-tenant front (many named databases behind one listener, per-tenant
admission budgets)::

    PYTHONPATH=src python -m repro.launch.serve query-server \
        --tenant teamA=runs/a --tenant teamB=runs/b,queue=64 [--follow]

Live ingest endpoint (continuous uploads -> incremental aggregation ->
versioned snapshots under the root)::

    PYTHONPATH=src python -m repro.launch.serve ingest runs/live \
        --port 8423 [--publish-every 64] [--retain 2] [--max-pending 256]

Regression watch (follow live roots, print one JSON findings report per
published epoch)::

    PYTHONPATH=src python -m repro.launch.serve watch nightly=runs/live \
        --baseline runs/baselines [--metric 0] [--poll-ms 250]

Each server prints one JSON line with its URL, then blocks until SIGINT
or SIGTERM.  SIGTERM drains gracefully: the endpoint stops accepting new
work (new calls get a structured ``503 Draining``), in-flight work gets
``--drain-timeout-s`` to finish, recorded spans are exported if
``--obs-export`` asked for them, and the process exits 0 — the contract
an orchestrator's rolling restart relies on.
"""
from __future__ import annotations

import argparse
import json
import signal
import sys
import threading
import time

import numpy as np


class _SignalWatch:
    """Two-phase signal wait: handlers are installed at construction —
    *before* the ready line is printed, because an orchestrator may
    SIGTERM the instant it sees it — and :meth:`wait` blocks until one
    arrives, restoring the previous handlers on the way out."""

    def __init__(self):
        self._got: dict = {}
        self._evt = threading.Event()
        self._old = {
            sig: signal.signal(sig, self._on)
            for sig in (signal.SIGTERM, signal.SIGINT)
        }

    def _on(self, signum, frame):
        self._got.setdefault("sig", signum)
        self._evt.set()

    def wait(self) -> str:
        try:
            while not self._evt.wait(0.5):
                pass
        finally:
            for sig, old in self._old.items():
                signal.signal(sig, old)
        return ("sigterm" if self._got.get("sig") == signal.SIGTERM
                else "sigint")


def _query_server_main(argv):
    from repro.query import Database
    from repro.serve.http import QueryHTTPServer

    ap = argparse.ArgumentParser(prog="repro.launch.serve query-server")
    ap.add_argument("db", nargs="?", default=None,
                    help="database directory (db.pms [+ db.cms/db.trc]); "
                         "omit when using --tenant")
    ap.add_argument("--tenant", action="append", default=None,
                    metavar="NAME=PATH[,queue=N]",
                    help="serve a named database behind this front "
                         "(repeatable -> multi-tenant: per-tenant "
                         "admission queues and metric labels; queue=N "
                         "overrides --max-queue for that tenant). "
                         "PATH is a database dir, or a snapshot root "
                         "under --follow")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8422,
                    help="0 picks a free port (printed on startup)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="micro-batch window size cap")
    ap.add_argument("--max-wait-ms", type=float, default=0.0,
                    help="max stall collecting a window after its first "
                         "request arrives (default 0: opportunistic — "
                         "serve what is queued, never stall an idle "
                         "worker; small positive values trade latency "
                         "for fuller windows under sparse bursty traffic)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission queue bound (per shard when sharded); "
                         "overflow answers 429")
    ap.add_argument("--shards", type=int, default=0,
                    help="N > 0 serves from N worker processes (one "
                         "Database + plane cache each, consistent-hash "
                         "routed by plane, supervisor respawns dead "
                         "workers); 0 = single-process")
    ap.add_argument("--shard-slab-mb", type=int, default=4,
                    help="shm slab size for sharded plane payloads")
    ap.add_argument("--replicas", type=int, default=2,
                    help="R-way plane ownership when sharded: each plane "
                         "has R successor-distinct owner shards; reads "
                         "fail over (and optionally hedge) across them")
    ap.add_argument("--transport", default="shm", choices=["shm", "tcp"],
                    help="parent<->shard-worker peer link: shm queues + "
                         "slab payloads (same host, default) or "
                         "length-prefixed TCP framing")
    ap.add_argument("--hedge-ms", type=float, default=None,
                    help="arm hedged reads: fire a duplicate at a live "
                         "replica after max(this, observed p99) and take "
                         "the first reply (default: off)")
    ap.add_argument("--max-connections", type=int, default=0,
                    help="cap concurrent keep-alive connections; beyond "
                         "it new connections get 429 + Retry-After "
                         "(0 = unlimited)")
    ap.add_argument("--drain-timeout-s", type=float, default=10.0,
                    help="SIGTERM grace: how long in-flight requests get "
                         "to finish before teardown")
    ap.add_argument("--no-adaptive-wait", action="store_true",
                    help="always hold batch windows for --max-wait-ms "
                         "instead of flushing when a worker idles")
    ap.add_argument("--workers", type=int, default=4,
                    help="window-serving workers on the runtime executor")
    ap.add_argument("--executor", default="threads",
                    choices=["threads", "serial"],
                    help="runtime backend for the serving loops")
    ap.add_argument("--cache-mb", type=int, default=64,
                    help="decoded-plane LRU budget")
    ap.add_argument("--warm-mb", type=int, default=None,
                    help="startup warming budget (default: 90%% of cache)")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip startup cache warming")
    ap.add_argument("--no-batching", action="store_true",
                    help="serve each HTTP call directly (baseline mode)")
    ap.add_argument("--timeout-s", type=float, default=30.0,
                    help="default per-request deadline")
    ap.add_argument("--follow", action="store_true",
                    help="treat the db argument as a live snapshot ROOT "
                         "(ingest output dir): open whatever CURRENT "
                         "points at and pick up new epochs without "
                         "restart")
    ap.add_argument("--poll-ms", type=float, default=250.0,
                    help="CURRENT-pointer poll interval under --follow")
    ap.add_argument("--follow-wait-s", type=float, default=60.0,
                    help="how long to wait for the first snapshot epoch "
                         "under --follow before giving up")
    ap.add_argument("--trace-ring", type=int, default=None,
                    help="flight-recorder ring capacity per process "
                         "(spans); 0 disables tracing, default: "
                         "REPRO_TRACE_RING or 2048")
    ap.add_argument("--obs-export", default=None, metavar="DIR",
                    help="on shutdown, export the recorded spans as a "
                         "trace-plane database under DIR (self-profiling: "
                         "analyze it with repro.launch.analyze query)")
    args = ap.parse_args(argv)

    warm_bytes = (0 if args.no_warm
                  else None if args.warm_mb is None else args.warm_mb << 20)
    kwargs = dict(host=args.host, port=args.port,
                  batching=not args.no_batching,
                  max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
                  max_queue=args.max_queue,
                  executor=args.executor, n_workers=args.workers,
                  default_timeout_s=args.timeout_s,
                  adaptive_wait=not args.no_adaptive_wait,
                  warm_bytes=warm_bytes, shards=args.shards,
                  shard_slab_bytes=args.shard_slab_mb << 20,
                  replicas=args.replicas, shard_transport=args.transport,
                  hedge_ms=args.hedge_ms,
                  max_connections=args.max_connections,
                  trace_ring=args.trace_ring)

    def _serve(srv, db):
        watch = _SignalWatch()
        info = {"url": srv.url, "batching": srv.batching,
                "shards": srv.shards, "replicas": args.replicas,
                "transport": args.transport, "profiles": db.n_profiles,
                "contexts": db.n_contexts, "warm": srv.warm_report}
        if srv.switcher is not None:
            info["epoch"] = srv.switcher.epoch
        if srv.multi_tenant:
            info["tenants"] = sorted(srv.tenants)
        print(json.dumps(info), flush=True)
        sig = watch.wait()
        if sig == "sigterm":
            report = srv.drain(timeout_s=args.drain_timeout_s)
            print(json.dumps({"drain": report}), file=sys.stderr, flush=True)
        print("shutting down", file=sys.stderr)
        if args.obs_export:
            from repro.obs import recorder
            from repro.obs.export import export_spans
            spans = recorder().snapshot()
            if spans:
                summary = export_spans(spans, args.obs_export)
                print(json.dumps({"obs_export": summary}),
                      file=sys.stderr, flush=True)
            else:
                print("obs-export: no spans recorded", file=sys.stderr)

    if bool(args.db) == bool(args.tenant):
        ap.error("pass a db directory or --tenant name=path (not both)")

    if args.tenant:
        from contextlib import ExitStack

        from repro.serve.tenant import parse_tenant_arg
        specs = [parse_tenant_arg(s) for s in args.tenant]
        queues = {name: q for name, _, q in specs if q is not None}
        with ExitStack() as stack:
            if args.follow:
                # each tenant follows its own snapshot root
                tenants = {name: path for name, path, _ in specs}
            else:
                tenants = {
                    name: stack.enter_context(
                        Database(path, cache_bytes=args.cache_mb << 20))
                    for name, path, _ in specs}
            srv = stack.enter_context(QueryHTTPServer(
                tenants=tenants, tenant_queues=queues or None,
                follow=args.follow, poll_ms=args.poll_ms,
                follow_wait_s=args.follow_wait_s,
                follow_cache_bytes=args.cache_mb << 20, **kwargs))
            _serve(srv, srv.db)
    elif args.follow:
        with QueryHTTPServer(args.db, follow=True, poll_ms=args.poll_ms,
                             follow_wait_s=args.follow_wait_s,
                             follow_cache_bytes=args.cache_mb << 20,
                             **kwargs) as srv:
            _serve(srv, srv.db)
    else:
        with Database(args.db, cache_bytes=args.cache_mb << 20) as db, \
                QueryHTTPServer(db, **kwargs) as srv:
            _serve(srv, db)


def _ingest_main(argv):
    from repro.core.aggregate import AggregationConfig
    from repro.ingest import IngestHTTPServer

    ap = argparse.ArgumentParser(prog="repro.launch.serve ingest")
    ap.add_argument("root", help="snapshot root (spool/ + epoch dirs + "
                                 "CURRENT live here)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8423,
                    help="0 picks a free port (printed on startup)")
    ap.add_argument("--executor", default="threads",
                    choices=["serial", "threads", "processes"],
                    help="runtime backend for incremental aggregation")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--max-pending", type=int, default=256,
                    help="spool backlog bound; overflow answers 429")
    ap.add_argument("--merge-batch", type=int, default=32,
                    help="max profiles folded into the state per merge")
    ap.add_argument("--publish-every", type=int, default=0,
                    help="auto-publish a snapshot each time this many new "
                         "profiles have merged (0 = only on /v1/publish)")
    ap.add_argument("--retain", type=int, default=2,
                    help="published epochs kept by GC (current and pinned "
                         "epochs always survive)")
    ap.add_argument("--max-body-mb", type=int, default=64,
                    help="largest accepted upload body")
    ap.add_argument("--no-traces", action="store_true",
                    help="skip the trace database in published snapshots")
    ap.add_argument("--drain-timeout-s", type=float, default=10.0,
                    help="SIGTERM grace: how long the merger gets to fold "
                         "the spooled backlog before teardown (anything "
                         "left is durable and recovered on restart)")
    args = ap.parse_args(argv)

    cfg = AggregationConfig(executor=args.executor, n_workers=args.workers,
                            write_traces=not args.no_traces)
    with IngestHTTPServer(args.root, host=args.host, port=args.port,
                          config=cfg, max_pending=args.max_pending,
                          merge_batch=args.merge_batch,
                          publish_every=args.publish_every,
                          retain=args.retain,
                          max_body_bytes=args.max_body_mb << 20) as srv:
        watch = _SignalWatch()
        cur = srv.store.current()
        print(json.dumps({"url": srv.url, "root": srv.root,
                          "epoch": cur[0] if cur else None,
                          "publish_every": srv.publish_every,
                          "retain": srv.retain}), flush=True)
        sig = watch.wait()
        if sig == "sigterm":
            report = srv.drain(timeout_s=args.drain_timeout_s)
            print(json.dumps({"drain": report}), file=sys.stderr, flush=True)
        print("shutting down", file=sys.stderr)


def _watch_main(argv):
    from repro.diagnose import RegressionWatch, WatchTarget

    ap = argparse.ArgumentParser(
        prog="repro.launch.serve watch",
        description="Regression watch: follow live snapshot roots and "
                    "print one JSON report line per published epoch — "
                    "regressions vs a baseline fleet plus trace-derived "
                    "findings (imbalance, stragglers, occupancy gaps).")
    ap.add_argument("targets", nargs="+", metavar="NAME=ROOT",
                    help="snapshot roots to follow, e.g. nightly=runs/live")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="baseline fleet: a database dir, or a dir of "
                         "database dirs; per-context noise bands come "
                         "from its variance")
    ap.add_argument("--metric", default="0",
                    help="metric id or name to compare (default 0)")
    ap.add_argument("--stat", default="sum",
                    choices=["sum", "mean", "max", "min", "count"])
    ap.add_argument("--analyzers", default="imbalance,straggler,"
                                           "occupancy_gap",
                    help="comma-separated trace analyzers per epoch "
                         "('' = regression-only)")
    ap.add_argument("--poll-ms", type=float, default=250.0)
    ap.add_argument("--z", type=float, default=3.0,
                    help="noise-band width in baseline stddevs")
    ap.add_argument("--rel-margin", type=float, default=0.05,
                    help="relative margin floor under the z-band")
    ap.add_argument("--min-value", type=float, default=0.0,
                    help="ignore paths below this absolute value")
    ap.add_argument("--wait-s", type=float, default=60.0,
                    help="how long to wait for each target's first epoch")
    args = ap.parse_args(argv)

    metric = int(args.metric) if args.metric.lstrip("-").isdigit() \
        else args.metric
    analyzers = tuple(a for a in args.analyzers.split(",") if a)
    targets = []
    for spec in args.targets:
        name, sep, root = spec.partition("=")
        if not sep or not root:
            ap.error(f"targets must be NAME=ROOT, got {spec!r}")
        targets.append(WatchTarget(
            name=name, root=root, baseline=args.baseline, metric=metric,
            stat=args.stat, analyzers=analyzers, z=args.z,
            rel_margin=args.rel_margin, min_value=args.min_value))

    def on_report(report):
        print(json.dumps(report.as_dict()), flush=True)

    with RegressionWatch(targets, poll_ms=args.poll_ms, wait_s=args.wait_s,
                         on_report=on_report) as watch:
        watcher = _SignalWatch()
        print(json.dumps({"watching": sorted(t.name for t in targets),
                          "baseline": args.baseline,
                          "poll_ms": args.poll_ms}), file=sys.stderr,
              flush=True)
        watcher.wait()
        print(json.dumps({"status": watch.status()}), file=sys.stderr,
              flush=True)
    print("shutting down", file=sys.stderr)


def _generate_main(argv):
    from repro.configs.base import get_arch, reduced
    from repro.models import params as PD
    from repro.models.api import build_model
    from repro.serve.engine import Request, ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    import jax.numpy as jnp
    params = PD.init_params(model.param_defs(), 0, jnp.float32)
    eng = ServeEngine(model, params,
                      max_len=args.prompt_len + args.new_tokens + 1,
                      max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size,
                                 args.prompt_len).astype(np.int32),
                    args.new_tokens) for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = eng.serve(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in outs)
    print(f"served {len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[:3]):
        print(f"req{i}: {o.tolist()}")


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "query-server":
        _query_server_main(argv[1:])
    elif argv and argv[0] == "ingest":
        _ingest_main(argv[1:])
    elif argv and argv[0] == "watch":
        _watch_main(argv[1:])
    else:
        _generate_main(argv)


if __name__ == "__main__":
    main()
