"""Post-mortem analysis CLI — the hpcprof analog.

    PYTHONPATH=src python -m repro.launch.analyze runs/profiles/*.rprf \
        --out runs/db --executor processes --workers 4 \
        [--ranks 2] [--heap] [--static-lb]
"""
from __future__ import annotations

import argparse
import json

from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.core.reduction import aggregate_multiprocess
from repro.runtime import available_executors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("profiles", nargs="+")
    ap.add_argument("--out", default="runs/db")
    ap.add_argument("--executor", default=None,
                    choices=available_executors(),
                    help="aggregation runtime backend (default: threads; "
                         "single-rank only)")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker count for the chosen executor "
                         "(default: --threads)")
    ap.add_argument("--threads", type=int, default=4,
                    help="legacy worker knob; --workers wins when given")
    ap.add_argument("--ranks", type=int, default=1,
                    help=">1 uses the MPI-analog multiprocess driver")
    ap.add_argument("--heap", action="store_true",
                    help="paper-faithful heap-merge CMS gather")
    ap.add_argument("--static-lb", action="store_true",
                    help="static context groups instead of GLB")
    ap.add_argument("--no-cms", action="store_true")
    ap.add_argument("--no-traces", action="store_true")
    args = ap.parse_args()

    if args.ranks > 1 and (args.executor is not None or args.workers is not None):
        ap.error("--executor/--workers select the single-rank runtime; "
                 "with --ranks > 1 use --threads (threads per rank)")
    cfg = AggregationConfig(
        n_threads=args.threads,
        executor=args.executor or "threads",
        n_workers=args.workers,
        cms_strategy="heap" if args.heap else "vectorized",
        cms_balance="static" if args.static_lb else "dynamic",
        write_cms=not args.no_cms,
        write_traces=not args.no_traces,
    )
    if args.ranks > 1:
        res = aggregate_multiprocess(args.profiles, args.out,
                                     n_ranks=args.ranks,
                                     threads_per_rank=args.threads,
                                     config=cfg)
    else:
        res = StreamingAggregator(args.out, cfg).run(args.profiles)
    runtime = (f"ranks={args.ranks}x{args.threads}t" if args.ranks > 1
               else cfg.executor)
    print(json.dumps({
        "pms": res.pms_path, "cms": res.cms_path, "traces": res.trace_path,
        "executor": runtime, "workers": cfg.workers,
        "profiles": res.n_profiles, "contexts": res.n_contexts,
        "values": res.n_values, "sizes": res.sizes,
        "timings": {k: round(v, 4) if isinstance(v, float) else v
                    for k, v in res.timings.items()},
    }, indent=2))


if __name__ == "__main__":
    main()
