"""Post-mortem analysis CLI — the hpcprof analog, plus the query engine.

Aggregate profiles into the PMS/CMS/trace databases::

    PYTHONPATH=src python -m repro.launch.analyze runs/profiles/*.rprf \
        --out runs/db --executor processes --workers 4 \
        [--heap] [--static-lb]

Query a completed database (``repro.query`` front end)::

    PYTHONPATH=src python -m repro.launch.analyze query runs/db \
        topk --metric 3 -k 10 [--exclusive]
    ... query runs/db select --path-regex 'attn' --metric 3 --min 1.5
    ... query runs/db stripe --ctx 7 --metric 3
    ... query runs/db diff runs/db_b --metric 3 --top 20
    ... query runs/db window --pid 0 --t0 0.0 --t1 1.0

Diagnose a database (trace-derived findings, optionally regressions vs a
baseline fleet)::

    PYTHONPATH=src python -m repro.launch.analyze diagnose runs/db \
        [--baseline runs/baselines] [--metric 3] [--analyzers imbalance] \
        [--markdown]

Every query subcommand prints one JSON document to stdout; ``diagnose
--markdown`` prints the findings table instead.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.aggregate import AggregationConfig, StreamingAggregator
from repro.runtime import available_executors


def _aggregate_main(argv):
    ap = argparse.ArgumentParser(prog="repro.launch.analyze")
    ap.add_argument("profiles", nargs="+")
    ap.add_argument("--out", default="runs/db")
    ap.add_argument("--executor", default=None,
                    choices=available_executors(),
                    help="aggregation runtime backend (default: threads); "
                         "'ranks' is the multi-rank MPI-analog driver")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker count (rank count for --executor ranks); "
                         "default: --threads")
    ap.add_argument("--threads", type=int, default=4,
                    help="legacy worker knob; threads-per-rank under ranks")
    ap.add_argument("--ranks", type=int, default=1,
                    help="legacy spelling of '--executor ranks --workers R'")
    ap.add_argument("--sink-window", type=int, default=None,
                    help="ordered-sink out-of-order plane bound "
                         "(default: 2 x workers; 0 = unbounded)")
    ap.add_argument("--heap", action="store_true",
                    help="paper-faithful heap-merge CMS gather")
    ap.add_argument("--static-lb", action="store_true",
                    help="static context groups instead of GLB")
    ap.add_argument("--no-cms", action="store_true")
    ap.add_argument("--no-traces", action="store_true")
    ap.add_argument("--compute", default="cpu", choices=["cpu", "device"],
                    help="phase-2 hot-loop backend: numpy, or the Pallas "
                         "kernels (falls back to cpu without an accelerator)")
    ap.add_argument("--device-interpret", action="store_true",
                    help="let --compute device run on the interpret-mode "
                         "kernel proxy when no accelerator is attached "
                         "(slow; exercises the real kernel bodies)")
    args = ap.parse_args(argv)

    executor = args.executor or "threads"
    workers = args.workers
    if args.ranks > 1:
        if args.executor not in (None, "ranks"):
            ap.error("--ranks selects the rank driver; it cannot combine "
                     "with a different --executor")
        executor = "ranks"
        workers = args.ranks if workers is None else workers
    cfg = AggregationConfig(
        n_threads=args.threads,
        executor=executor,
        n_workers=workers,
        sink_window=args.sink_window,
        cms_strategy="heap" if args.heap else "vectorized",
        cms_balance="static" if args.static_lb else "dynamic",
        write_cms=not args.no_cms,
        write_traces=not args.no_traces,
        compute=args.compute,
        device_interpret=args.device_interpret,
    )
    res = StreamingAggregator(args.out, cfg).run(args.profiles)
    runtime = (f"ranks={cfg.workers}x{args.threads}t"
               if executor == "ranks" else executor)
    print(json.dumps({
        "pms": res.pms_path, "cms": res.cms_path, "traces": res.trace_path,
        "executor": runtime, "workers": cfg.workers,
        "compute": cfg.effective_compute(),
        "profiles": res.n_profiles, "contexts": res.n_contexts,
        "values": res.n_values, "sizes": res.sizes,
        "timings": {k: round(v, 4) if isinstance(v, float) else v
                    for k, v in res.timings.items()},
    }, indent=2))


# ---------------------------------------------------------------------------
# query front end
# ---------------------------------------------------------------------------

def _metric_arg(ap):
    ap.add_argument("--metric", required=True,
                    help="metric id (int) or registry name; ':I' suffix or "
                         "--inclusive selects the propagated variant")
    ap.add_argument("--inclusive", action="store_true")
    ap.add_argument("--stat", default="sum",
                    choices=["sum", "mean", "min", "max", "count", "std"])


def _parse_metric(v: str):
    try:
        return int(v)
    except ValueError:
        return v


def _query_main(argv):
    from repro.query import (Database, diff, occupancy, samples_in_window,
                             select_contexts, threshold_contexts,
                             topk_hot_paths)

    ap = argparse.ArgumentParser(prog="repro.launch.analyze query")
    ap.add_argument("db", help="database directory (db.pms [+ db.cms/db.trc])")
    sub = ap.add_subparsers(dest="op", required=True)

    p = sub.add_parser("topk", help="k hottest call paths")
    _metric_arg(p)
    p.add_argument("-k", type=int, default=10)
    p.add_argument("--exclusive", action="store_true",
                   help="rank by exclusive instead of inclusive cost")

    p = sub.add_parser("select", help="contexts by path predicate / threshold")
    _metric_arg(p)
    p.add_argument("--path-regex", default=None)
    p.add_argument("--min", type=float, default=0.0,
                   help="summary-stat threshold (default 0: all non-zeros)")

    p = sub.add_parser("stripe", help="one metric of one context, all profiles")
    _metric_arg(p)
    p.add_argument("--ctx", type=int, required=True)

    p = sub.add_parser("diff", help="cross-run regression diff")
    p.add_argument("db_b", help="second database directory")
    _metric_arg(p)
    p.add_argument("--top", type=int, default=20)

    p = sub.add_parser("window", help="trace samples + occupancy in a window")
    p.add_argument("--pid", type=int, default=None,
                   help="restrict to one profile (default: all, occupancy only)")
    p.add_argument("--t0", type=float, required=True)
    p.add_argument("--t1", type=float, required=True)
    p.add_argument("--top", type=int, default=10)

    args = ap.parse_args(argv)
    with Database(args.db) as db:
        if args.op == "topk":
            rows = topk_hot_paths(db, _parse_metric(args.metric), k=args.k,
                                  inclusive=not args.exclusive, stat=args.stat)
            out = {"op": "topk", "rows": [h.as_dict() for h in rows]}
        elif args.op == "select":
            within = (select_contexts(db, path_regex=args.path_regex)
                      if args.path_regex else None)
            ctx, vals = threshold_contexts(
                db, _parse_metric(args.metric), min_value=args.min,
                stat=args.stat, inclusive=args.inclusive, within=within)
            out = {"op": "select",
                   "rows": [{"ctx": int(c), "path": db.path_of(int(c)),
                             args.stat: float(v)}
                            for c, v in zip(ctx, vals)]}
        elif args.op == "stripe":
            prof, vals = db.stripe(args.ctx, _parse_metric(args.metric),
                                   inclusive=args.inclusive)
            out = {"op": "stripe", "ctx": args.ctx,
                   "path": db.path_of(args.ctx),
                   "profiles": [int(p) for p in prof],
                   "values": [float(v) for v in vals]}
        elif args.op == "diff":
            with Database(args.db_b) as db_b:
                rows = diff(db, db_b, _parse_metric(args.metric),
                            stat=args.stat, inclusive=args.inclusive,
                            top=args.top)
                out = {"op": "diff", "rows": [e.as_dict() for e in rows]}
        elif args.op == "window":
            ctx, counts = occupancy(
                db, args.t0, args.t1,
                pids=None if args.pid is None else [args.pid])
            order = (-counts).argsort(kind="stable")[:args.top]
            out = {"op": "window", "t0": args.t0, "t1": args.t1,
                   "n_samples": int(counts.sum()),
                   "occupancy": [{"ctx": int(ctx[i]),
                                  "path": db.path_of(int(ctx[i])),
                                  "samples": int(counts[i])}
                                 for i in order]}
            if args.pid is not None:
                win = samples_in_window(db, args.pid, args.t0, args.t1)
                out["pid"] = args.pid
                out["times"] = [float(t) for t in win.time[:1000]]
        print(json.dumps(out, indent=2))


def _diagnose_main(argv):
    from repro.analysis.report import findings_table
    from repro.diagnose import (DEFAULT_ANALYZERS, BaselineFleet,
                                compute_findings, regression_findings,
                                sort_findings)
    from repro.query import Database

    ap = argparse.ArgumentParser(
        prog="repro.launch.analyze diagnose",
        description="Run the diagnosis analyzers over a database: "
                    "trace-derived findings (load imbalance, stragglers, "
                    "occupancy gaps) plus, with --baseline, regressions "
                    "against a baseline fleet's noise bands.")
    ap.add_argument("db", help="database directory (db.pms [+ db.trc])")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="baseline fleet: a database dir, or a dir of "
                         "database dirs")
    ap.add_argument("--metric", default="0",
                    help="metric id (int) or registry name")
    ap.add_argument("--stat", default="sum",
                    choices=["sum", "mean", "min", "max", "count"])
    ap.add_argument("--inclusive", action="store_true")
    ap.add_argument("--analyzers", default=",".join(DEFAULT_ANALYZERS),
                    help="comma-separated trace analyzers "
                         "('' = regression-only)")
    ap.add_argument("--z", type=float, default=3.0,
                    help="noise-band width in baseline stddevs")
    ap.add_argument("--rel-margin", type=float, default=0.05,
                    help="relative margin floor under the z-band")
    ap.add_argument("--min-value", type=float, default=0.0,
                    help="ignore paths below this absolute value")
    ap.add_argument("--limit", type=int, default=0,
                    help="keep only the N most severe findings")
    ap.add_argument("--markdown", action="store_true",
                    help="print a findings table instead of JSON")
    args = ap.parse_args(argv)

    metric = _parse_metric(args.metric)
    analyzers = tuple(a for a in args.analyzers.split(",") if a)
    findings = []
    with Database(args.db) as db:
        if args.baseline:
            with BaselineFleet.from_dir(args.baseline) as fleet:
                findings += regression_findings(
                    db, fleet, metric, stat=args.stat,
                    inclusive=args.inclusive, z=args.z,
                    rel_margin=args.rel_margin, min_value=args.min_value)
        if analyzers:
            findings += compute_findings(db, analyzers=analyzers,
                                         metric=metric,
                                         inclusive=args.inclusive)
    findings = sort_findings(findings, args.limit or None)
    if args.markdown:
        print(findings_table(findings))
    else:
        print(json.dumps({"op": "diagnose", "db": args.db,
                          "baseline": args.baseline,
                          "count": len(findings),
                          "findings": [f.as_dict() for f in findings]},
                         indent=2))


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "query":
        _query_main(argv[1:])
    elif argv and argv[0] == "diagnose":
        _diagnose_main(argv[1:])
    else:
        _aggregate_main(argv)


if __name__ == "__main__":
    main()
