"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: every cell
must ``.lower().compile()`` for the single-pod (16x16 = 256 chip) and
multi-pod (2x16x16 = 512 chip) production meshes, and reports
``memory_analysis()`` (fits?) + ``cost_analysis()`` + collective bytes
(the §Roofline inputs).

Usage::

    python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    python -m repro.launch.dryrun --all --out runs/dryrun
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k \
        --set causal_mode=triangle --microbatches 4   # hillclimb variants
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax
# locks the device count on first init, so this precedes every import.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.analysis import roofline
from repro.configs.base import SHAPES, load_all
from repro.launch.mesh import make_production_mesh
from repro.models import params as PD
from repro.models.api import (batch_specs, batch_struct, build_model,
                              cache_struct_and_specs, model_flops,
                              n_active_params, n_params, rules_for)
from repro.sharding.specs import set_rules
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig
from repro.utils.jaxcompat import cost_analysis_dict

# long-context decode requires sub-quadratic history handling: only the
# SSM/hybrid archs run long_500k (DESIGN.md §Arch-applicability).
LONG_OK = {"zamba2-7b", "xlstm-350m"}


def cell_is_skipped(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch not in LONG_OK:
        return "full-attention arch: 500k dense KV decode is out of family"
    return None


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                overrides: dict | None = None, microbatches: int = 1,
                fsdp: bool | None = None, seq_shard: bool = False,
                donate: bool = True) -> dict:
    archs = load_all()
    cfg = archs[arch]
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    if shape.kind == "train" and microbatches == 1 \
            and shape.global_batch * shape.seq_len >= 1 << 20:
        # default gradient accumulation: bounds per-layer activation
        # residuals (the remat-saved per-layer carries) at ~1/8th; deep
        # stacks (zamba2: 81 layers) save a carry per layer -> go deeper
        microbatches = 16 if cfg.n_layers > 64 else 8
    # largest models (grok-1): f32 AdamW state alone exceeds a pod's HBM
    # (316e9 x 14 B/param = 4.4 TB > 256 x 16 GB) — physics, not sharding.
    # Runnable config: bf16 moments + bf16 grad accumulation (10 B/param)
    # and deeper accumulation.
    moment_dtype = jnp.float32
    accum_dtype = jnp.float32
    if shape.kind == "train" and 14 * n_params(cfg) / n_chips > 8e9:
        moment_dtype = jnp.bfloat16
        accum_dtype = jnp.bfloat16
        microbatches = max(microbatches, 16)
    kind = shape.kind
    rules_kind = "decode_sp" if (kind == "decode" and
                                 shape.global_batch < mesh.shape["data"]) \
        else kind
    rules = rules_for(cfg, mesh, rules_kind, fsdp=fsdp, seq_shard=seq_shard)
    model = build_model(cfg)
    dtype = jnp.dtype(cfg.dtype)

    defs = model.param_defs()
    params_sds = PD.shapedtypes(defs, dtype)
    pspecs = _named(PD.specs(defs, rules), mesh)
    bs_sds = batch_struct(cfg, shape)
    bspecs = _named(batch_specs(cfg, shape, rules), mesh)

    t0 = time.perf_counter()
    with mesh, set_rules(mesh, rules):
        if kind == "train":
            opt_sds = {
                "m": PD.shapedtypes(defs, moment_dtype),
                "v": PD.shapedtypes(defs, moment_dtype),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            ospecs = {"m": pspecs, "v": pspecs,
                      "step": NamedSharding(mesh, jax.sharding.PartitionSpec())}
            step = make_train_step(model, AdamWConfig(), mesh=mesh,
                                   rules=rules, microbatches=microbatches,
                                   accum_dtype=accum_dtype)
            fn = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                         donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(params_sds, opt_sds, bs_sds)
        elif kind == "prefill":
            fn = jax.jit(lambda p, b: model.prefill(p, b),
                         in_shardings=(pspecs, bspecs))
            lowered = fn.lower(params_sds, bs_sds)
        else:  # decode
            cache_sds, cache_specs = cache_struct_and_specs(model, cfg, shape, rules)
            cspecs = _named(cache_specs, mesh)
            fn = jax.jit(lambda p, c, b: model.decode_step(p, c, b),
                         in_shardings=(pspecs, cspecs, bspecs),
                         donate_argnums=(1,) if donate else ())
            lowered = fn.lower(params_sds, cache_sds, bs_sds)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    rf = roofline.analyze(compiled, n_chips=n_chips,
                          model_flops=model_flops(cfg, shape))
    ca = cost_analysis_dict(compiled)
    hbm_per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "kind": kind, "rules_kind": rules_kind,
        "n_params": n_params(cfg), "n_active_params": n_active_params(cfg),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": hbm_per_dev,
            "fits_16GiB": bool(hbm_per_dev < 16 * 2**30),
        },
        "roofline": rf.to_dict(),
        "collectives": rf.coll_by_kind,
        "xla_cost_analysis": {"flops": ca.get("flops", 0.0),
                              "bytes_accessed": ca.get("bytes accessed", 0.0)},
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
        "overrides": overrides or {}, "microbatches": microbatches,
        "moment_dtype": str(jnp.dtype(moment_dtype)),
    }


def _parse_overrides(items):
    out = {}
    for kv in items or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--set", dest="sets", action="append",
                    help="ModelConfig override k=v (hillclimb lever)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fsdp", choices=["auto", "on", "off"], default="auto")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]
    overrides = _parse_overrides(args.sets)

    if not args.all:
        skip = cell_is_skipped(args.arch, args.shape)
        if skip:
            print(json.dumps({"arch": args.arch, "shape": args.shape,
                              "skipped": skip}))
            return
        res = dryrun_cell(args.arch, args.shape, multi_pod=args.multipod,
                          overrides=overrides, microbatches=args.microbatches,
                          fsdp=fsdp, seq_shard=args.seq_shard)
        print(json.dumps(res, indent=2))
        if args.tag:
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, f"{args.tag}.json"), "w") as f:
                json.dump(res, f, indent=2)
        return

    os.makedirs(args.out, exist_ok=True)
    archs = sorted(load_all())
    ok = fail = skipped = 0
    for multi_pod in (False, True):
        for arch in archs:
            for shape_name in SHAPES:
                mesh_tag = "multi" if multi_pod else "single"
                tag = f"{arch}.{shape_name}.{mesh_tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    ok += 1
                    continue
                skip = cell_is_skipped(arch, shape_name)
                if skip:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape_name,
                                   "mesh": mesh_tag, "skipped": skip}, f)
                    skipped += 1
                    continue
                t0 = time.perf_counter()
                try:
                    res = dryrun_cell(arch, shape_name, multi_pod=multi_pod)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=2)
                    ok += 1
                    print(f"OK   {tag:48s} {time.perf_counter()-t0:6.1f}s "
                          f"dom={res['roofline']['dominant']:10s} "
                          f"mem={res['memory']['peak_per_device_bytes']/2**30:6.2f}GiB",
                          flush=True)
                except Exception as e:
                    fail += 1
                    with open(path + ".err", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"FAIL {tag:48s} {type(e).__name__}: {str(e)[:120]}",
                          flush=True)
    print(f"done: ok={ok} fail={fail} skipped={skipped}")


if __name__ == "__main__":
    main()
