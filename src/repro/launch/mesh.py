"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  Target hardware is a
TPU v5e pod: 256 chips arranged (16 data x 16 model); multi-pod adds a
leading ``pod`` axis (2 x 16 x 16 = 512 chips).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` exists from jax 0.5; older jaxlibs get the default
    (equivalent: every axis auto-sharded)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over forced host devices for tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             **_mesh_kwargs(3))
    return jax.make_mesh((data, model), ("data", "model"), **_mesh_kwargs(2))
