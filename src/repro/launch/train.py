"""Training launcher.

Runs a (reduced or full) architecture with the full substrate: sharded
train step, deterministic data pipeline, async checkpoints, straggler
watchdog, and the paper's measurement subsystem writing per-worker sparse
profiles for post-mortem analysis.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 50 --profile-dir runs/profiles
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_arch, reduced
from repro.data import TokenPipeline
from repro.models.api import build_model
from repro.profiling import Profiler
from repro.train.loop import Trainer, TrainerConfig, make_train_step
from repro.train.optimizer import AdamWConfig
from repro.utils.jaxcompat import cost_analysis_dict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--profile-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    profiler = Profiler({"rank": 0, "stream": 0, "kind": "host"}) \
        if args.profile_dir else None
    tr = Trainer(model, AdamWConfig(lr=args.lr, warmup_steps=10),
                 TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                               microbatches=args.microbatches,
                               deadline_s=30.0),
                 pipe, ckpt=ckpt, profiler=profiler)
    start = 0
    params = opt = None
    if args.resume and ckpt is not None:
        step, state = ckpt.restore()
        if state is not None:
            start = step
            params, opt = state["params"], state["opt"]
            params = jax.tree_util.tree_map(jnp.asarray, params)
            opt = jax.tree_util.tree_map(jnp.asarray, opt)
            print(f"resumed from step {step}")
    if params is None:
        params, opt = tr.init_state()

    if profiler is not None:
        compiled = jax.jit(make_train_step(model, AdamWConfig())).lower(
            params, opt, {"tokens": jnp.asarray(pipe.batch_at(start))}).compile()
        ca = cost_analysis_dict(compiled)
        profiler.attribute_compiled(compiled.as_text(),
                                    measured={"flops": ca.get("flops", 0.0)},
                                    struct_dir=os.path.join(args.profile_dir,
                                                            "structs"))

    params, opt = tr.run(params, opt, start_step=start, steps=args.steps)
    print(json.dumps(tr.history[-3:], indent=2))
    if profiler is not None:
        os.makedirs(args.profile_dir, exist_ok=True)
        profiler.finish(os.path.join(args.profile_dir, "worker0.rprf"))
        print(f"profile written to {args.profile_dir}/worker0.rprf")


if __name__ == "__main__":
    main()
