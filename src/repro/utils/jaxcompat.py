"""Small shims over jax API drift so one tree runs on every installed jax.

Keep every version-dependent accessor here; callers stay clean.
"""
from __future__ import annotations


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jax version.

    jax <= 0.4.x returns a one-element list of dicts (per computation);
    jax >= 0.5 returns the dict directly; either may be empty/None.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
