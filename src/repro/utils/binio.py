"""Binary I/O helpers shared by the profile / PMS / CMS file formats.

Layout conventions (little-endian throughout):

* json block : [u32 length][utf-8 bytes]
* array block: [4s dtype code][u8 ndim][u64 x ndim shape][raw C-order bytes]

These helpers exist so every on-disk format in :mod:`repro.core` measures its
exact byte footprint (the paper's evaluation is in bytes, Tables 1/2/4).

Zero-copy contract: :func:`unpack_array` returns a *view* over the caller's
buffer (``np.frombuffer`` with an offset, never an intermediate slice), so
decoding a profile from an ``mmap`` aliases the page cache instead of
materializing a private copy.  The view keeps the backing buffer alive; it
is read-only whenever the buffer is (bytes, ``ACCESS_READ`` maps) — callers
that need to mutate must copy explicitly, exactly as before.
"""
from __future__ import annotations

import json
import struct
from typing import BinaryIO

import numpy as np

_DTYPE_CODES = {
    "u8  ": np.dtype(np.uint8),
    "u16 ": np.dtype(np.uint16),
    "u32 ": np.dtype(np.uint32),
    "u64 ": np.dtype(np.uint64),
    "i32 ": np.dtype(np.int32),
    "i64 ": np.dtype(np.int64),
    "f32 ": np.dtype(np.float32),
    "f64 ": np.dtype(np.float64),
}
_CODE_FOR_DTYPE = {v: k for k, v in _DTYPE_CODES.items()}


def pack_json(obj) -> bytes:
    payload = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    return struct.pack("<I", len(payload)) + payload


def unpack_json(buf: bytes, off: int = 0):
    (n,) = struct.unpack_from("<I", buf, off)
    off += 4
    obj = json.loads(buf[off : off + n].decode("utf-8"))
    return obj, off + n


def write_json(f: BinaryIO, obj) -> int:
    data = pack_json(obj)
    f.write(data)
    return len(data)


def read_json(f: BinaryIO):
    (n,) = struct.unpack("<I", f.read(4))
    return json.loads(f.read(n).decode("utf-8"))


def pack_array(arr: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(arr)
    code = _CODE_FOR_DTYPE[arr.dtype]
    head = code.encode("ascii") + struct.pack("<B", arr.ndim)
    head += struct.pack(f"<{arr.ndim}Q", *arr.shape)
    return head + arr.tobytes()


def packed_nbytes(arr: np.ndarray) -> int:
    """Size of :func:`pack_array`'s output without materializing it."""
    return 5 + 8 * arr.ndim + arr.nbytes


def pack_array_into(view, off: int, arr: np.ndarray) -> int:
    """Write the :func:`pack_array` layout directly into a writable buffer
    (a bytearray or shared-memory view) at ``off``; returns the new offset.

    Byte-for-byte identical to ``pack_array`` — the slab transport and the
    pickle transport must produce the same plane payloads.
    """
    arr = np.ascontiguousarray(arr)
    code = _CODE_FOR_DTYPE[arr.dtype]
    view[off : off + 4] = code.encode("ascii")
    struct.pack_into("<B", view, off + 4, arr.ndim)
    struct.pack_into(f"<{arr.ndim}Q", view, off + 5, *arr.shape)
    off += 5 + 8 * arr.ndim
    if arr.nbytes:
        dst = np.frombuffer(view, dtype=np.uint8, count=arr.nbytes, offset=off)
        dst[:] = arr.reshape(-1).view(np.uint8)
    return off + arr.nbytes


def unpack_array(buf, off: int = 0):
    code = bytes(buf[off : off + 4]).decode("ascii")
    dtype = _DTYPE_CODES[code]
    off += 4
    (ndim,) = struct.unpack_from("<B", buf, off)
    off += 1
    shape = struct.unpack_from(f"<{ndim}Q", buf, off)
    off += 8 * ndim
    count = int(np.prod(shape)) if ndim else 1
    nbytes = count * dtype.itemsize
    # a view over the caller's buffer (page cache for mmaps), not a copy
    arr = np.frombuffer(buf, dtype=dtype, count=count, offset=off).reshape(shape)
    return arr, off + nbytes


def write_array(f: BinaryIO, arr: np.ndarray) -> int:
    data = pack_array(arr)
    f.write(data)
    return len(data)


def read_array(f: BinaryIO) -> np.ndarray:
    code = f.read(4).decode("ascii")
    dtype = _DTYPE_CODES[code]
    (ndim,) = struct.unpack("<B", f.read(1))
    shape = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
    count = int(np.prod(shape)) if ndim else 1
    return np.frombuffer(f.read(count * dtype.itemsize), dtype=dtype).reshape(shape)
