"""Deterministic sharded token pipeline.

Batches are a pure function of ``(seed, step, shard)`` so:

* restart-from-checkpoint resumes the exact data stream (cursor = step);
* **elastic rescale** is exact: re-sharding to a different data-parallel
  extent partitions the same global batch differently but yields identical
  global content (tested);
* a configurable per-host delay hook simulates stragglers for the
  watchdog tests.

The generator mixes a counter-based hash (SplitMix64-style) so there is no
RNG state to checkpoint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    shard: int = 0
    n_shards: int = 1
    seed: int = 0
    delay_s: float = 0.0   # straggler-injection hook (tests)

    def __post_init__(self):
        assert self.global_batch % self.n_shards == 0
        self.local_batch = self.global_batch // self.n_shards

    def batch_at(self, step: int) -> np.ndarray:
        """(local_batch, seq_len) int32 for this shard at ``step``."""
        if self.delay_s:
            time.sleep(self.delay_s)
        rows = (np.arange(self.local_batch, dtype=np.uint64)
                + np.uint64(self.shard * self.local_batch))
        cols = np.arange(self.seq_len, dtype=np.uint64)
        base = (np.uint64(self.seed) * np.uint64(0x100000001)
                + np.uint64(step) * np.uint64(self.global_batch * self.seq_len))
        idx = base + rows[:, None] * np.uint64(self.seq_len) + cols[None, :]
        return (_splitmix64(idx) % np.uint64(self.vocab_size)).astype(np.int32)

    def global_batch_at(self, step: int) -> np.ndarray:
        full = TokenPipeline(self.vocab_size, self.seq_len, self.global_batch,
                             shard=0, n_shards=1, seed=self.seed)
        return full.batch_at(step)

    def state(self, step: int) -> dict:
        return {"step": step, "seed": self.seed}

    def resharded(self, shard: int, n_shards: int) -> "TokenPipeline":
        return TokenPipeline(self.vocab_size, self.seq_len, self.global_batch,
                             shard=shard, n_shards=n_shards, seed=self.seed)
