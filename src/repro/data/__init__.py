from repro.data.pipeline import TokenPipeline

__all__ = ["TokenPipeline"]
