"""Typed diagnosis records: the unit of output for every analyzer.

A ``Finding`` is one structured statement about a run — "this call path
regressed 2.1x against its baseline band", "rank 7 logged 3x the median
trace samples" — with enough evidence attached that a human (or the next
tool) never has to re-run the query that produced it.

Findings are value objects: frozen, orderable by a deterministic severity
key, and round-trippable through plain dicts so they travel the serve
wire protocol and land in JSON reports unchanged.  Determinism matters
beyond aesthetics — sharded serving computes findings per-shard and
merges by concatenation + this sort, so the sort key must totally order
any finding set the analyzers can emit.
"""
from __future__ import annotations

from dataclasses import dataclass, field

SEVERITIES = ("info", "warning", "critical")
_RANK = {s: i for i, s in enumerate(SEVERITIES)}


def severity_for(score: float) -> str:
    """Map an analyzer score to a severity.

    ``score`` is normalized badness: observed / threshold (or band edge),
    so 1.0 is "exactly at the line".  Analyzers only emit findings at
    score >= 1, hence nothing here maps to ``info`` — that level is
    reserved for advisory findings (new call paths, missing baselines)
    that analyzers mint explicitly.
    """
    return "critical" if score >= 2.0 else "warning"


@dataclass(frozen=True)
class Finding:
    """One diagnosis: what is wrong, where, and the numbers behind it."""

    kind: str             # regression | load_imbalance | straggler | occupancy_gap | new_path
    severity: str         # one of SEVERITIES
    score: float          # normalized badness; >= 1 means "over the line"
    message: str          # one human-readable sentence
    ctx: int = -1         # context id (call-path findings; -1 otherwise)
    path: str = ""        # full call path string when ctx is set
    pid: int = -1         # profile/rank id (per-rank findings; -1 otherwise)
    metric: str = ""      # metric label the evidence is in ("" when n/a)
    value: float = 0.0    # observed quantity
    expected: float = 0.0 # reference: band edge, threshold, or baseline mean
    t0: float = 0.0       # trace span of the evidence (both 0: no span)
    t1: float = 0.0
    evidence: dict = field(default_factory=dict, compare=False)

    def sort_key(self):
        """Severity desc, score desc, then stable structural tiebreaks."""
        return (-_RANK.get(self.severity, 0), -self.score,
                self.kind, self.ctx, self.pid, self.path)

    def as_dict(self) -> dict:
        return {"kind": self.kind, "severity": self.severity,
                "score": self.score, "message": self.message,
                "ctx": self.ctx, "path": self.path, "pid": self.pid,
                "metric": self.metric, "value": self.value,
                "expected": self.expected, "t0": self.t0, "t1": self.t1,
                "evidence": dict(self.evidence)}

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(kind=str(d.get("kind", "")),
                   severity=str(d.get("severity", "info")),
                   score=float(d.get("score", 0.0)),
                   message=str(d.get("message", "")),
                   ctx=int(d.get("ctx", -1)), path=str(d.get("path", "")),
                   pid=int(d.get("pid", -1)), metric=str(d.get("metric", "")),
                   value=float(d.get("value", 0.0)),
                   expected=float(d.get("expected", 0.0)),
                   t0=float(d.get("t0", 0.0)), t1=float(d.get("t1", 0.0)),
                   evidence=dict(d.get("evidence") or {}))


def sort_findings(findings: list[Finding], limit: int | None = None
                  ) -> list[Finding]:
    """The canonical ordering every producer (and shard merge) applies."""
    out = sorted(findings, key=Finding.sort_key)
    return out[:limit] if limit else out
