"""Regression watch: continuous diagnosis over the live epoch stream.

The ingest tier publishes versioned snapshots behind a ``CURRENT``
pointer; a :class:`RegressionWatch` follows one or more snapshot roots
with the same :class:`~repro.query.epoch.EpochSwitcher` machinery the
``--follow`` server uses, and evaluates **every newly published epoch**
against its baseline fleet inside the poll loop itself — detection
latency is bounded by one poll interval plus the evaluation time, both of
which it measures.

Per evaluation it emits:

* typed :class:`~repro.diagnose.findings.Finding` records (kept in a
  bounded history, handed to an optional ``on_report`` callback);
* ``watch.*`` metrics through the obs registry — evaluation latency
  histogram, per-severity finding counters, poll/error counters;
* one ``watch`` span per evaluation in the flight recorder, and a ring
  dump when an evaluation surfaces critical findings (so the spans
  *around* the regression are preserved for postmortem).

One watch supervises many targets — the multi-tenant pattern is one
``WatchTarget`` per team's snapshot root.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field

from repro.diagnose.analyzers import (compute_findings, regression_findings,
                                      sort_findings)
from repro.diagnose.baseline import BaselineFleet
from repro.diagnose.findings import Finding
from repro.obs import FlightRecorder, MetricsRegistry, monotime, recorder
from repro.query.epoch import EpochSwitcher, wait_for_epoch


@dataclass
class WatchTarget:
    """One supervised snapshot root and its evaluation recipe."""

    name: str
    root: str
    #: a BaselineFleet, a directory path for the watch to open (and own),
    #: or None: trace analyzers only
    baseline: BaselineFleet | str | None = None
    metric: object = 0
    stat: str = "sum"
    inclusive: bool = True
    analyzers: tuple = ()       # extra scatter-clean analyzers per epoch
    z: float = 3.0
    rel_margin: float = 0.05
    abs_margin: float = 0.0
    min_value: float = 0.0
    thresholds: dict = field(default_factory=dict)


@dataclass(frozen=True)
class EpochReport:
    """The outcome of evaluating one published epoch of one target."""

    target: str
    epoch: int
    findings: tuple
    eval_s: float

    @property
    def worst(self) -> str:
        return self.findings[0].severity if self.findings else "none"

    def as_dict(self) -> dict:
        return {"target": self.target, "epoch": self.epoch,
                "eval_s": self.eval_s, "worst": self.worst,
                "findings": [f.as_dict() for f in self.findings]}


class _TargetState:
    def __init__(self, target: WatchTarget, switcher: EpochSwitcher,
                 owned_baseline: BaselineFleet | None = None):
        self.target = target
        self.switcher = switcher
        self.owned_baseline = owned_baseline  # opened from a path: we close
        self.latest: EpochReport | None = None

    @property
    def baseline(self) -> BaselineFleet | None:
        if self.owned_baseline is not None:
            return self.owned_baseline
        b = self.target.baseline
        return b if isinstance(b, BaselineFleet) else None


class RegressionWatch:
    """Follow snapshot roots; diagnose each new epoch within a poll tick."""

    def __init__(self, targets, *, poll_ms: float = 250.0,
                 cache_bytes: int = 64 << 20, wait_s: float = 60.0,
                 history: int = 256, on_report=None,
                 rec: FlightRecorder | None = None):
        if isinstance(targets, WatchTarget):
            targets = [targets]
        if not targets:
            raise ValueError("RegressionWatch needs at least one target")
        names = [t.name for t in targets]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate watch target names: {names}")
        self._targets = list(targets)
        self.poll_ms = float(poll_ms)
        self.cache_bytes = int(cache_bytes)
        self.wait_s = float(wait_s)
        self.on_report = on_report
        self._rec = rec if rec is not None else recorder()
        self._states: dict[str, _TargetState] = {}
        self._history: deque[EpochReport] = deque(maxlen=int(history))
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

        self.obs = MetricsRegistry()
        self._eval_hist = self.obs.histogram("watch.eval_latency")
        self.counters = self.obs.group(
            "watch", {"polls": 0, "epochs": 0, "errors": 0, "findings": 0,
                      "critical": 0, "warning": 0, "info": 0})
        self.obs.gauge("watch.targets", lambda: len(self._targets))

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "RegressionWatch":
        """Open every target (waiting for its first epoch) and evaluate it
        once, so a watch pointed at an already-regressed stream flags it
        immediately; then begin the poll thread."""
        for t in self._targets:
            wait_for_epoch(t.root, timeout_s=self.wait_s)
            owned = (BaselineFleet.from_dir(t.baseline)
                     if isinstance(t.baseline, str) else None)
            st = _TargetState(t, EpochSwitcher(t.root,
                                               cache_bytes=self.cache_bytes),
                              owned_baseline=owned)
            self._states[t.name] = st
            self._evaluate(st)
        self._thread = threading.Thread(target=self._loop,
                                        name="regression-watch", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        for st in self._states.values():
            st.switcher.close()
            if st.owned_baseline is not None:
                st.owned_baseline.close()
        self._states.clear()

    def __enter__(self) -> "RegressionWatch":
        return self.start()

    def __exit__(self, *a) -> None:
        self.stop()

    # -- the loop -------------------------------------------------------------
    def _loop(self) -> None:
        while not self._stop.wait(self.poll_ms / 1000.0):
            self.poll_once()

    def poll_once(self) -> int:
        """One poll pass over every target; returns epochs evaluated.
        Public so tests (and cron-style drivers) can step deterministically."""
        evaluated = 0
        self.counters.inc("polls")
        for st in self._states.values():
            try:
                if st.switcher.poll():
                    self._evaluate(st)
                    evaluated += 1
            except Exception:
                # SnapshotGone after retry, torn reads mid-publish: count
                # and keep watching — the next poll sees a settled pointer
                self.counters.inc("errors")
        return evaluated

    def _evaluate(self, st: _TargetState) -> EpochReport:
        t = st.target
        t0 = monotime()
        db = st.switcher.db
        epoch = st.switcher.epoch or 0
        findings: list[Finding] = []
        baseline = st.baseline
        if baseline is not None:
            findings += regression_findings(
                db, baseline, t.metric, stat=t.stat, inclusive=t.inclusive,
                z=t.z, rel_margin=t.rel_margin, abs_margin=t.abs_margin,
                min_value=t.min_value)
        if t.analyzers:
            findings += compute_findings(
                db, analyzers=t.analyzers, metric=t.metric,
                thresholds=t.thresholds or None)
        findings = sort_findings(findings)
        dur = monotime() - t0

        self._eval_hist.observe(dur)
        self.counters.inc("epochs")
        self.counters.inc("findings", len(findings))
        for f in findings:
            self.counters.inc(f.severity)
        self._rec.record("watch", t.name, t0, dur,
                         attrs={"epoch": epoch, "findings": len(findings)})
        if findings and findings[0].severity == "critical":
            self._rec.dump(f"critical findings: target={t.name} "
                           f"epoch={epoch}")

        report = EpochReport(target=t.name, epoch=epoch,
                             findings=tuple(findings), eval_s=dur)
        with self._lock:
            st.latest = report
            self._history.append(report)
        if self.on_report is not None:
            self.on_report(report)
        return report

    # -- inspection -----------------------------------------------------------
    def latest(self, name: str) -> EpochReport | None:
        with self._lock:
            st = self._states.get(name)
            return st.latest if st is not None else None

    def reports(self, name: str | None = None) -> list[EpochReport]:
        with self._lock:
            return [r for r in self._history
                    if name is None or r.target == name]

    def status(self) -> dict:
        with self._lock:
            targets = {
                n: {"epoch": st.latest.epoch if st.latest else None,
                    "findings": len(st.latest.findings) if st.latest else 0,
                    "worst": st.latest.worst if st.latest else "none",
                    "eval_s": st.latest.eval_s if st.latest else 0.0}
                for n, st in self._states.items()}
        return {"poll_ms": self.poll_ms, "targets": targets,
                "counters": dict(self.counters),
                "eval_latency": self._eval_hist.as_dict()}
