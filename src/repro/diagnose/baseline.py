"""Baseline fleets: per-call-path noise bands learned from known-good runs.

A regression verdict against a *single* baseline run can't distinguish a
slowdown from run-to-run weather.  A fleet of baselines gives each call
path a distribution — mean and spread across runs — and the band's upper
edge scales with that observed variance: ``mean + max(z*std,
rel_margin*mean, abs_margin)``.  A fleet of byte-identical runs has
std 0 everywhere, so the band collapses to the relative margin and any
real bump fires; a noisy path earns a wide band and stops crying wolf.

Paths absent from some baseline runs contribute 0 for those runs — the
band then straddles "sometimes present", which is the honest prior.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass

from repro.query.database import PMS_NAME, Database
from repro.query.diff import metric_stats_by_path


@dataclass(frozen=True)
class PathBand:
    """One call path's cost distribution over the baseline fleet."""

    path: str
    mean: float   # mean cost across runs (absent runs count as 0)
    std: float    # population std across runs
    n: int        # fleet size

    def hi(self, *, z: float = 3.0, rel_margin: float = 0.05,
           abs_margin: float = 0.0) -> float:
        """Upper band edge: widest of the statistical and floor margins."""
        return self.mean + max(z * self.std, rel_margin * self.mean,
                               abs_margin)


class BaselineFleet:
    """A set of baseline databases and the bands computed over them.

    Construct from already-open :class:`Database` handles, or with
    :meth:`from_dir` which opens every database directory found under a
    root (sorted by name, so band arithmetic is order-deterministic).
    Bands are memoized per ``(metric, stat, inclusive)``.
    """

    def __init__(self, dbs: list[Database], *, owned: bool = False):
        if not dbs:
            raise ValueError("BaselineFleet needs at least one baseline run")
        self._dbs = list(dbs)
        self._owned = owned
        self._bands: dict[tuple, dict[str, PathBand]] = {}

    @classmethod
    def from_dir(cls, root, *, cache_bytes: int = 32 << 20
                 ) -> "BaselineFleet":
        """Open every db under ``root`` (or ``root`` itself if it is one).

        A directory counts as a run if it contains ``db.pms`` — so a plain
        collection of analyze outputs and a snapshot root's epoch dirs
        both work unmodified.
        """
        root = str(root)
        dirs: list[str] = []
        if os.path.exists(os.path.join(root, PMS_NAME)):
            dirs.append(root)
        else:
            for name in sorted(os.listdir(root)):
                cand = os.path.join(root, name)
                if os.path.isdir(cand) and \
                        os.path.exists(os.path.join(cand, PMS_NAME)):
                    dirs.append(cand)
        if not dirs:
            raise FileNotFoundError(
                f"no databases (dirs containing {PMS_NAME}) under {root}")
        return cls([Database(d, cache_bytes=cache_bytes) for d in dirs],
                   owned=True)

    @property
    def n_runs(self) -> int:
        return len(self._dbs)

    def bands(self, metric, *, stat: str = "sum", inclusive: bool = True
              ) -> dict[str, PathBand]:
        key = (str(metric), stat, bool(inclusive))
        hit = self._bands.get(key)
        if hit is not None:
            return hit
        n = len(self._dbs)
        acc: dict[str, list[float]] = {}
        for db in self._dbs:
            for path, (_ctx, v, _s) in metric_stats_by_path(
                    db, metric, stat, inclusive).items():
                acc.setdefault(path, []).append(v)
        out: dict[str, PathBand] = {}
        for path, vals in acc.items():
            # absent runs contribute 0 so mean/std reflect the whole fleet
            s = sum(vals)
            mean = s / n
            var = sum((v - mean) ** 2 for v in vals) + \
                (n - len(vals)) * mean ** 2
            std = math.sqrt(max(var / n, 0.0))
            out[path] = PathBand(path=path, mean=mean, std=std, n=n)
        self._bands[key] = out
        return out

    def close(self) -> None:
        if self._owned:
            for db in self._dbs:
                db.close()
        self._dbs = []
        self._bands.clear()

    def __enter__(self) -> "BaselineFleet":
        return self

    def __exit__(self, *a) -> None:
        self.close()
