"""Continuous diagnosis: analyzers, baselines, and the regression watch.

The analysis tier's product layer — instead of leaving humans to run
ad-hoc diffs and timeline queries, this package turns the read stack's
primitives into typed :class:`Finding` records, continuously, against
the live epoch stream.  See docs/diagnosis.md.
"""
from repro.diagnose.analyzers import (DEFAULT_ANALYZERS, DEFAULT_THRESHOLDS,
                                      compute_findings, imbalance_findings,
                                      occupancy_gap_findings,
                                      regression_findings,
                                      straggler_findings)
from repro.diagnose.baseline import BaselineFleet, PathBand
from repro.diagnose.findings import (SEVERITIES, Finding, severity_for,
                                     sort_findings)
from repro.diagnose.watch import EpochReport, RegressionWatch, WatchTarget

__all__ = [
    "SEVERITIES",
    "DEFAULT_ANALYZERS",
    "DEFAULT_THRESHOLDS",
    "BaselineFleet",
    "EpochReport",
    "Finding",
    "PathBand",
    "RegressionWatch",
    "WatchTarget",
    "compute_findings",
    "imbalance_findings",
    "occupancy_gap_findings",
    "regression_findings",
    "severity_for",
    "sort_findings",
    "straggler_findings",
]
