"""Analyzers: pure functions ``Database -> list[Finding]``.

Every analyzer here is **scatter-clean**: it reads only the summary-stats
section and the trace table of contents (plus, for occupancy gaps, the
trace segments of the profiles it is asked about) — data every shard of a
sharded server holds in full.  Each finding depends only on its own
context or profile plus *global* aggregates (metric totals, the fleet
median sample count) that are identical on every shard, so partitioning
the ctx/pid space across shards and concatenating the partial finding
lists reproduces the single-process answer exactly.

The exception is :func:`regression_findings`, which needs a baseline
fleet (a set of other databases) — that runs in the watch service and the
offline CLI, where the baselines live, not inside the serve op.
"""
from __future__ import annotations

import numpy as np

from repro.core.loadbalance import imbalance_ratio
from repro.diagnose.findings import Finding, severity_for, sort_findings
from repro.query.database import Database
from repro.query.diff import metric_stats_by_path
from repro.query.timeline import samples_in_window

DEFAULT_ANALYZERS = ("imbalance", "straggler", "occupancy_gap")

# flat threshold knobs, overridable per request via the ``thresholds`` dict
DEFAULT_THRESHOLDS = {
    "imbalance": 2.0,     # flag ctx where max/mean >= this
    "min_share": 0.01,    # ...and the ctx carries >= 1% of the metric total
    "straggler": 1.5,     # flag ranks with >= 1.5x the median sample count
    "min_samples": 8,     # ignore ranks with fewer trace samples than this
    "gap_frac": 0.25,     # flag ranks idle for >= 25% of their active span
}


def _metric_label(metric, inclusive: bool) -> str:
    lab = metric if isinstance(metric, str) else str(int(metric))
    return f"{lab}:I" if inclusive and not lab.endswith(":I") else lab


def imbalance_findings(db: Database, metric=0, *, inclusive: bool = False,
                       threshold: float = 2.0, min_share: float = 0.01,
                       within_ctx: np.ndarray | None = None
                       ) -> list[Finding]:
    """Per-context load imbalance λ = max/mean from summary stats alone.

    The hot gate (``min_share`` of the *global* metric total) keeps noise
    contexts out; the total is computed before any ownership mask so every
    shard applies the identical gate.
    """
    try:
        ctx_ids, rows = db.metric_entries(metric, inclusive=inclusive)
    except (KeyError, ValueError, IndexError):
        return []
    if rows.size == 0:
        return []
    s = db.stats["sum"][rows].astype(np.float64)
    cnt = db.stats["count"][rows]
    vmax = db.stats["max"][rows]
    mean = db.stats["mean"][rows]
    total = float(s.sum())  # global — before masking, shard-invariant
    lam = imbalance_ratio(vmax, mean)
    share = s / total if total > 0 else np.zeros_like(s)
    elig = (cnt >= 2) & (share >= min_share) & (lam >= threshold)
    if within_ctx is not None:
        elig &= within_ctx[ctx_ids.astype(np.int64)]
    label = _metric_label(metric, inclusive)
    out: list[Finding] = []
    for i in np.flatnonzero(elig):
        c, l = int(ctx_ids[i]), float(lam[i])
        score = l / threshold
        out.append(Finding(
            kind="load_imbalance", severity=severity_for(score), score=score,
            ctx=c, path=db.path_of(c), metric=label, value=l,
            expected=threshold,
            message=(f"context {c} is {l:.2f}x imbalanced across "
                     f"{int(cnt[i])} profiles ({share[i]:.1%} of metric "
                     f"{label} total)"),
            evidence={"max": float(vmax[i]), "mean": float(mean[i]),
                      "count": int(cnt[i]), "share": float(share[i])}))
    return out


def straggler_findings(db: Database, *, threshold: float = 1.5,
                       min_samples: int = 8,
                       within_pid: np.ndarray | None = None
                       ) -> list[Finding]:
    """Ranks whose trace sample count dwarfs the fleet median.

    Under uniform sampling, sample count is proportional to active time,
    so a rank with 2x the median samples worked (or waited inside
    instrumented code) twice as long — the classic straggler signature.
    Reads only the trace toc: zero segment decodes.
    """
    counts = db.trace_lengths()
    if counts.size == 0:
        return []
    med = float(np.median(counts))  # global, identical on every shard
    ref = max(med, 1.0)
    ratio = counts / ref
    elig = (ratio >= threshold) & (counts >= min_samples)
    if within_pid is not None:
        elig &= within_pid[:counts.size]
    out: list[Finding] = []
    for p in np.flatnonzero(elig):
        p = int(p)
        score = float(ratio[p]) / threshold
        out.append(Finding(
            kind="straggler", severity=severity_for(score), score=score,
            pid=p, value=float(counts[p]), expected=ref * threshold,
            message=(f"rank {p} logged {int(counts[p])} trace samples, "
                     f"{ratio[p]:.2f}x the fleet median of {med:.0f}"),
            evidence={"samples": int(counts[p]), "median": med,
                      "ranks": int(counts.size)}))
    return out


def occupancy_gap_findings(db: Database, *, gap_frac: float = 0.25,
                           min_samples: int = 8,
                           within_pid: np.ndarray | None = None
                           ) -> list[Finding]:
    """Ranks with a large internal idle hole in their own activity.

    For each rank: the biggest gap between consecutive trace samples,
    relative to that rank's active span.  A 25% hole means the device sat
    idle (or uninstrumented) for a quarter of its run — the occupancy-gap
    shape GPU timelines surface visually, computed here from the samples.
    Decodes only the asked-about ranks' segments, so a shard pays for its
    own profiles only.
    """
    counts = db.trace_lengths()
    out: list[Finding] = []
    for p in range(counts.size):
        if counts[p] < min_samples:
            continue
        if within_pid is not None and not within_pid[p]:
            continue
        tr = samples_in_window(db, p, -np.inf, np.inf)
        t = np.asarray(tr.time, dtype=np.float64)
        if t.size < 2:
            continue
        span = float(t[-1] - t[0])
        if span <= 0.0:
            continue
        gaps = np.diff(t)
        gi = int(np.argmax(gaps))
        frac = float(gaps[gi]) / span
        score = frac / gap_frac
        if score < 1.0:
            continue
        out.append(Finding(
            kind="occupancy_gap", severity=severity_for(score), score=score,
            pid=p, value=frac, expected=gap_frac,
            t0=float(t[gi]), t1=float(t[gi + 1]),
            message=(f"rank {p} idle {float(gaps[gi]):.4f}s "
                     f"({frac:.0%} of its {span:.4f}s active span)"),
            evidence={"gap_s": float(gaps[gi]), "span_s": span,
                      "samples": int(counts[p])}))
    return out


def compute_findings(db: Database, *, analyzers=None, metric=None,
                     inclusive: bool = False, limit: int = 0,
                     thresholds: dict | None = None,
                     within_ctx: np.ndarray | None = None,
                     within_pid: np.ndarray | None = None) -> list[Finding]:
    """Run the scatter-clean analyzers and return one sorted finding list.

    This is the body of the serve-tier ``findings`` op: ``within_ctx`` /
    ``within_pid`` are the shard's ownership masks (None: everything), and
    the output ordering is the canonical :func:`sort_findings` order so a
    concat-and-resort merge is byte-identical to the unsharded answer.
    """
    chosen = tuple(analyzers) if analyzers else DEFAULT_ANALYZERS
    th = dict(DEFAULT_THRESHOLDS)
    for k, v in (thresholds or {}).items():
        if k not in th:
            raise ValueError(f"unknown threshold {k!r}; "
                             f"known: {sorted(th)}")
        th[k] = float(v)
    metric = 0 if metric is None else metric
    out: list[Finding] = []
    for name in chosen:
        if name == "imbalance":
            out += imbalance_findings(
                db, metric, inclusive=inclusive, threshold=th["imbalance"],
                min_share=th["min_share"], within_ctx=within_ctx)
        elif name == "straggler":
            out += straggler_findings(
                db, threshold=th["straggler"],
                min_samples=int(th["min_samples"]), within_pid=within_pid)
        elif name == "occupancy_gap":
            out += occupancy_gap_findings(
                db, gap_frac=th["gap_frac"],
                min_samples=int(th["min_samples"]), within_pid=within_pid)
        else:
            raise ValueError(f"unknown analyzer {name!r}; "
                             f"known: {list(DEFAULT_ANALYZERS)}")
    return sort_findings(out, limit or None)


def regression_findings(db: Database, baseline, metric=0, *,
                        stat: str = "sum", inclusive: bool = True,
                        z: float = 3.0, rel_margin: float = 0.05,
                        abs_margin: float = 0.0, min_value: float = 0.0,
                        flag_new_paths: bool = False, limit: int = 0
                        ) -> list[Finding]:
    """Diff one run against a baseline fleet's per-path noise bands.

    A path is flagged when its cost exceeds ``mean + max(z*std,
    rel_margin*mean, abs_margin)`` over the fleet — the band widens with
    observed baseline variance, so noisy paths need a bigger excursion to
    fire while a fleet of identical runs (std 0) flags any bump past the
    relative margin.  ``baseline`` is a :class:`~repro.diagnose.baseline.
    BaselineFleet``.
    """
    bands = baseline.bands(metric, stat=stat, inclusive=inclusive)
    run = metric_stats_by_path(db, metric, stat, inclusive)
    label = _metric_label(metric, inclusive)
    out: list[Finding] = []
    for path, (ctx, v, _std) in run.items():
        band = bands.get(path)
        if band is None:
            if flag_new_paths and v > max(abs_margin, min_value):
                out.append(Finding(
                    kind="new_path", severity="info", score=0.0,
                    ctx=ctx, path=path, metric=label, value=v,
                    message=f"path absent from all {baseline.n_runs} "
                            f"baseline runs now costs {v:.4g}"))
            continue
        hi = band.hi(z=z, rel_margin=rel_margin, abs_margin=abs_margin)
        if v <= hi or v < min_value:
            continue
        width = max(hi - band.mean, 1e-12)
        score = (v - band.mean) / width
        ratio = v / band.mean if band.mean else float("inf")
        out.append(Finding(
            kind="regression", severity=severity_for(score), score=score,
            ctx=ctx, path=path, metric=label, value=v, expected=hi,
            message=(f"{path} costs {v:.4g}, {ratio:.2f}x its baseline "
                     f"mean {band.mean:.4g} (band limit {hi:.4g}, "
                     f"n={band.n})"),
            evidence={"baseline_mean": band.mean, "baseline_std": band.std,
                      "n_baseline": band.n, "ratio": ratio}))
    return sort_findings(out, limit or None)
