"""Versioned database snapshots: epoch directories + atomic ``CURRENT``.

A snapshot root published by the ingest tier looks like::

    root/
      CURRENT                      -> {"epoch": 7, "dir": "epoch-0000000007"}
      epoch-0000000006/            (kept by the retention window)
        db.pms  db.cms  db.trc
        MANIFEST.json
      epoch-0000000007/
        ...

Publication protocol (crash-safe at every step):

1. the database files are written into a hidden staging directory
   (``.tmp-epoch-N``) that no reader ever resolves;
2. ``MANIFEST.json`` (epoch, file list with sizes, schema version) is
   written and fsync'd, then every database file and the staging directory
   itself are fsync'd — after this the snapshot is durably complete;
3. the staging directory is renamed to ``epoch-N`` (atomic on POSIX) and
   the root is fsync'd;
4. ``CURRENT`` is replaced via write-temp + fsync + ``os.rename`` + root
   fsync — readers either see the old pointer or the new one, never a
   partial file.

A crash before step 4 leaves ``CURRENT`` pointing at the previous epoch
and at worst an orphaned staging/epoch directory; the next publication
picks the next free epoch number and :meth:`SnapshotStore.gc` sweeps
stale staging directories.

Retention: :meth:`gc` keeps the newest ``retain`` epochs.  It never
removes the current epoch, and never removes an epoch that a local reader
has pinned (:meth:`pin` — the refcount the query tier holds while a
snapshot serves in-flight batches).  Readers that open an epoch directly
and lose the race with GC get :class:`SnapshotGone` — resolve ``CURRENT``
again and retry.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

CURRENT_NAME = "CURRENT"
MANIFEST_NAME = "MANIFEST.json"
EPOCH_PREFIX = "epoch-"
_STAGE_PREFIX = ".tmp-epoch-"
SCHEMA_VERSION = 1


class SnapshotGone(RuntimeError):
    """The epoch directory a reader resolved no longer exists (GC won the
    race, or ``CURRENT`` points mid-publish at a not-yet-visible epoch).
    Retryable: re-read ``CURRENT`` and open the fresh epoch."""


def epoch_dirname(epoch: int) -> str:
    return f"{EPOCH_PREFIX}{int(epoch):010d}"


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def read_current(root) -> tuple[int, str] | None:
    """Resolve ``root/CURRENT`` -> ``(epoch, absolute_epoch_dir)``;
    ``None`` when nothing has been published yet."""
    path = os.path.join(str(root), CURRENT_NAME)
    try:
        with open(path, "rb") as f:
            obj = json.loads(f.read().decode("utf-8"))
    except FileNotFoundError:
        return None
    return int(obj["epoch"]), os.path.join(str(root), obj["dir"])


def read_manifest(epoch_dir: str) -> dict:
    try:
        with open(os.path.join(epoch_dir, MANIFEST_NAME), "rb") as f:
            return json.loads(f.read().decode("utf-8"))
    except FileNotFoundError as e:
        raise SnapshotGone(f"no manifest under {epoch_dir}") from e


class SnapshotStore:
    """Owner side of a snapshot root: publish epochs, GC old ones.

    One process owns publication (the ingest server); readers only ever
    resolve ``CURRENT`` and open epoch directories, so they need no store
    object at all (:func:`read_current` /
    :meth:`repro.query.Database.open_current`).
    """

    def __init__(self, root):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()
        self._pins: dict[int, int] = {}  # epoch -> refcount

    # -- introspection -------------------------------------------------------
    def current(self) -> tuple[int, str] | None:
        return read_current(self.root)

    def epochs(self) -> list[int]:
        """Published epoch numbers on disk, ascending."""
        out = []
        for name in os.listdir(self.root):
            if name.startswith(EPOCH_PREFIX):
                try:
                    out.append(int(name[len(EPOCH_PREFIX):]))
                except ValueError:
                    pass
        return sorted(out)

    def epoch_dir(self, epoch: int) -> str:
        return os.path.join(self.root, epoch_dirname(epoch))

    # -- publication ---------------------------------------------------------
    def publish(self, write_fn, extra_meta: dict | None = None
                ) -> tuple[int, str]:
        """Publish one epoch: ``write_fn(staging_dir)`` writes the database
        files, then the manifest/rename/CURRENT dance makes them visible.
        Returns ``(epoch, epoch_dir)``.  On any failure the staging
        directory is removed and ``CURRENT`` is untouched.
        """
        with self._lock:
            known = self.epochs()
            cur = self.current()
            epoch = max(known + [cur[0] if cur else 0]) + 1
            stage = os.path.join(self.root, f"{_STAGE_PREFIX}{epoch:010d}")
            final = self.epoch_dir(epoch)
            if os.path.exists(stage):
                shutil.rmtree(stage)
            os.makedirs(stage)
            try:
                write_fn(stage)
                files = sorted(f for f in os.listdir(stage)
                               if f != MANIFEST_NAME)
                manifest = {
                    "schema": SCHEMA_VERSION, "epoch": epoch,
                    "files": {f: os.path.getsize(os.path.join(stage, f))
                              for f in files},
                }
                manifest.update(extra_meta or {})
                mpath = os.path.join(stage, MANIFEST_NAME)
                with open(mpath, "w", encoding="utf-8") as f:
                    json.dump(manifest, f, indent=1)
                    f.flush()
                    os.fsync(f.fileno())
                for fname in files:
                    _fsync_path(os.path.join(stage, fname))
                _fsync_path(stage)
            except BaseException:
                shutil.rmtree(stage, ignore_errors=True)
                raise
            os.rename(stage, final)
            _fsync_path(self.root)
            self._write_current(epoch)
            return epoch, final

    def _write_current(self, epoch: int) -> None:
        """Atomic ``CURRENT`` swing; a crash at any point leaves a valid
        (old or new) pointer because ``os.rename`` replaces atomically."""
        tmp = os.path.join(self.root, CURRENT_NAME + ".tmp")
        blob = json.dumps({"epoch": int(epoch),
                           "dir": epoch_dirname(epoch)}).encode("utf-8")
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.root, CURRENT_NAME))
        _fsync_path(self.root)

    # -- pinning (local readers) ---------------------------------------------
    def pin(self, epoch: int) -> "_Pin":
        """Hold ``epoch`` against GC while a reader serves from it."""
        with self._lock:
            self._pins[int(epoch)] = self._pins.get(int(epoch), 0) + 1
        return _Pin(self, int(epoch))

    def _unpin(self, epoch: int) -> None:
        with self._lock:
            left = self._pins.get(epoch, 0) - 1
            if left > 0:
                self._pins[epoch] = left
            else:
                self._pins.pop(epoch, None)

    def pinned_epochs(self) -> set[int]:
        with self._lock:
            return set(self._pins)

    # -- retention -----------------------------------------------------------
    def gc(self, retain: int = 2) -> list[int]:
        """Remove epochs older than the newest ``retain``; returns the
        epochs removed.  The current epoch and pinned epochs always
        survive, as do stale staging directories younger than the lock
        (they are swept too, they just don't count against retention).
        """
        retain = max(1, int(retain))
        removed: list[int] = []
        with self._lock:
            cur = self.current()
            keep = set(self.epochs()[-retain:])
            if cur is not None:
                keep.add(cur[0])
            keep |= set(self._pins)
            for epoch in self.epochs():
                if epoch not in keep:
                    shutil.rmtree(self.epoch_dir(epoch), ignore_errors=True)
                    removed.append(epoch)
            # orphaned staging dirs from crashed publications
            for name in os.listdir(self.root):
                if name.startswith(_STAGE_PREFIX):
                    shutil.rmtree(os.path.join(self.root, name),
                                  ignore_errors=True)
        return removed


class _Pin:
    """Context-manager handle for one :meth:`SnapshotStore.pin`."""

    def __init__(self, store: SnapshotStore, epoch: int):
        self._store = store
        self.epoch = epoch
        self._released = False

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._store._unpin(self.epoch)

    def __enter__(self) -> "_Pin":
        return self

    def __exit__(self, *a) -> None:
        self.release()
