"""Typed stdlib client for the ingest endpoint.

Rides the same transport/retry machinery as the query-side
:class:`~repro.serve.client.QueryClient` — one keep-alive connection,
429 -> :class:`~repro.serve.client.ServerOverloaded`, other failures ->
:class:`~repro.serve.client.TransportError` — so one
:class:`~repro.serve.client.RetryPolicy` drives upload loops the same way
it drives query loops: backpressure bursts (the merger falling behind)
are ridden out with jittered backoff honoring the server's ``Retry-After``
hint, structural failures (a non-RPRF blob -> 400, an oversize body ->
413) fail fast.
"""
from __future__ import annotations

import base64
import time

from repro.serve.client import JSONClient, RetryPolicy


class IngestClient(JSONClient):
    """Client for :class:`~repro.ingest.server.IngestHTTPServer`."""

    # -- uploads --------------------------------------------------------------
    def upload(self, blob: bytes) -> dict:
        """Upload one serialized profile (the ``RPRF`` bytes that
        ``MeasurementProfile.save`` writes)."""
        return self._roundtrip("POST", "/v1/ingest", raw=bytes(blob),
                               content_type="application/octet-stream")

    def upload_many(self, blobs: list[bytes]) -> dict:
        """Upload a batch of profiles in one call (JSON + base64 envelope;
        all-or-nothing admission, so a 429 rejects the whole batch)."""
        body = {"profiles": [base64.b64encode(bytes(b)).decode("ascii")
                             for b in blobs]}
        return self._roundtrip("POST", "/v1/ingest", body)

    def upload_files(self, paths: list) -> dict:
        """Upload profile *files* (reads them; does not delete them)."""
        blobs = []
        for p in paths:
            with open(p, "rb") as f:
                blobs.append(f.read())
        return self.upload_many(blobs)

    def upload_with_retry(self, blobs: list[bytes], *,
                          policy: RetryPolicy | None = None,
                          sleep=time.sleep) -> dict:
        """:meth:`upload_many` under a :class:`RetryPolicy`: rides out
        429 backpressure, fails fast on 400/413."""
        policy = policy or RetryPolicy()
        return policy.call(lambda: self.upload_many(blobs), sleep=sleep)

    # -- control --------------------------------------------------------------
    def publish(self) -> dict:
        """Drain the spool and publish the next snapshot epoch."""
        return self._roundtrip("POST", "/v1/publish", {})

    def epochs(self) -> dict:
        return self._roundtrip("GET", "/v1/epochs")

    # -- service introspection -------------------------------------------------
    def health(self) -> dict:
        return self._roundtrip("GET", "/healthz")

    def metrics(self) -> dict:
        return self._roundtrip("GET", "/metrics")
