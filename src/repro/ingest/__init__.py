"""Live ingest tier: continuous profile uploads -> incremental aggregation
-> versioned database snapshots (ROADMAP item 1; paper §4's streaming
premise taken online).

* :class:`~repro.ingest.state.IngestState` — resident aggregation whose
  phase boundary is an *append*;
* :class:`~repro.ingest.snapshot.SnapshotStore` — epoch directories,
  atomic ``CURRENT`` pointer, retention GC;
* :class:`~repro.ingest.server.IngestHTTPServer` — the upload endpoint;
* :class:`~repro.ingest.client.IngestClient` — typed client with retries.
"""
from repro.ingest.client import IngestClient
from repro.ingest.server import IngestHTTPServer
from repro.ingest.snapshot import (SnapshotGone, SnapshotStore,
                                   epoch_dirname, read_current,
                                   read_manifest)
from repro.ingest.state import IngestState, relabel_plane

__all__ = [
    "IngestState", "relabel_plane",
    "IngestHTTPServer", "IngestClient",
    "SnapshotStore", "SnapshotGone", "epoch_dirname", "read_current",
    "read_manifest",
]
