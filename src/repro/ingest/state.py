"""Incremental aggregation state: the phase boundary as an *append*.

A one-shot :class:`~repro.core.aggregate.StreamingAggregator` run sees every
profile before it renumbers the unified CCT and streams phase 2.  The live
ingest tier cannot — profiles arrive forever — so :class:`IngestState` keeps
the aggregation *resident* and lets new batches merge into it:

* the unified tree is grown in place by phase 1
  (:func:`~repro.core.aggregate.phase1_unify_inprocess` with ``unified=``);
  node ids are **creation-order** ids, which are stable under later appends
  — the coordinate system everything resident is stored in;
* each batch streams through the same fused phase-2 engines as a one-shot
  run (:func:`~repro.core.aggregate.phase2_stream_inprocess` /
  :func:`~repro.core.aggregate.phase2_stream_sharded`, shm slab arena and
  all), transformed in the *batch's* canonical preorder (the fused kernel
  needs contiguous subtree intervals), then relabeled to stable ids by the
  consume hook and retained: encoded planes, remapped traces, per-profile
  statistics pushed into a persistent carry-chain reducer;
* :meth:`write_database` renumbers to the *current* canonical preorder and
  writes a complete PMS/CMS/trace database for a snapshot epoch.

**Byte parity with a one-shot run** (proven by ``tests/test_ingest.py``):
a database published after N appends is byte-identical to one ``analyze``
over the same profiles in the same order.  The argument:

* canonical preorder keeps the *relative* order of pre-existing nodes when
  new nodes are inserted (children sort by content, and new subtrees only
  shift positions), so batch-preorder -> final-preorder is order-preserving
  on the nodes a batch could reference;
* the fused phase-2 kernel's FP op order depends only on the relative order
  of a profile's own triplets and subtree intervals — invariant under an
  order-preserving relabel; contexts created by later batches carry zeros
  for earlier profiles and are absent from their triplets entirely;
* :func:`relabel_plane` is a pure permutation (values move by fancy
  indexing; no arithmetic, no combining — unlike ``from_triplets``), so a
  stored plane re-labeled at publish time is the same floats the one-shot
  transform would have produced;
* statistics segments group by key; a bijective key relabel permutes
  segments without reordering *within* any segment (equal-key rows keep
  concatenation = profile order), so per-key reductions see identical
  operand sequences; the carry chain's merge shape is a pure function of
  the total profile count, which appends preserve by construction.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import cms as cms_mod
from repro.core.aggregate import (AggregationConfig, _merge_stats,
                                  _PhaseTimer, _renumber,
                                  phase1_unify_inprocess,
                                  phase2_stream_inprocess,
                                  phase2_stream_sharded)
from repro.core.cct import ContextTree
from repro.core.pms import PMSWriter
from repro.core.sparse import CTX_DTYPE, IDX_DTYPE, SparseMetrics, Trace
from repro.core.stats import StatsAccumulator
from repro.core.traces import TraceDBWriter
from repro.runtime import get_executor
from repro.runtime.reduce import StreamingReducer


def relabel_plane(sm: SparseMetrics, mapping: np.ndarray) -> SparseMetrics:
    """Rebuild a canonical CSR plane under a bijective context relabel.

    Values and metric ids move by fancy indexing only — no summation, no
    zero-dropping (``from_triplets`` would do both) — so the result is the
    exact permutation of the input floats, which is what the byte-parity
    contract requires.  (ctx, mid) keys are unique in a canonical plane, so
    the lexsort permutation is unique regardless of sort stability.
    """
    if sm.ctx.size == 0:
        return SparseMetrics.empty()
    rows = np.repeat(sm.ctx.astype(np.int64),
                     np.diff(sm.start.astype(np.int64)))
    new_rows = np.asarray(mapping, dtype=np.int64)[rows]
    order = np.lexsort((sm.mid, new_rows))
    r = new_rows[order]
    bounds = np.flatnonzero(np.diff(r, prepend=-1))
    starts = np.concatenate([bounds, [r.size]]).astype(IDX_DTYPE)
    return SparseMetrics(r[bounds].astype(CTX_DTYPE), starts,
                         np.ascontiguousarray(sm.mid[order]),
                         np.ascontiguousarray(sm.val[order]))


def _relabel_stat_arrays(arrs: dict, mapping: np.ndarray) -> dict:
    """Relabel the packed (ctx << 16 | mid) keys of compacted statistics
    arrays; all value columns are carried as-is (row order untouched —
    the next merge's stable sort regroups by key)."""
    keys = np.asarray(arrs["keys"], np.uint64)
    ctx = (keys >> np.uint64(16)).astype(np.int64)
    new_keys = ((np.asarray(mapping, np.int64)[ctx].astype(np.uint64)
                 << np.uint64(16)) | (keys & np.uint64(0xFFFF)))
    out = dict(arrs)
    out["keys"] = new_keys
    return out


def _snapshot_reduce(reducer: StreamingReducer) -> StatsAccumulator | None:
    """Non-destructive :meth:`StreamingReducer.result`: fold *copies* of the
    live slots in the same order, leaving the carry chain intact so later
    appends keep extending the same deterministic merge shape."""
    acc = None
    for slot in reversed(reducer._slots):
        if slot is None:
            continue
        clone = StatsAccumulator.from_arrays(
            {k: np.array(v, copy=True) for k, v in slot.to_arrays().items()})
        acc = clone if acc is None else _merge_stats(acc, clone)
    return acc


class IngestState:
    """Resident aggregation: append profile batches, publish databases.

    Single-owner by design — the ingest server drives one instance from its
    merger thread; :meth:`append` and :meth:`write_database` are not
    thread-safe against each other.
    """

    def __init__(self, config: AggregationConfig | None = None):
        self.cfg = config or AggregationConfig()
        if self.cfg.executor not in ("serial", "threads", "processes"):
            raise ValueError(
                f"ingest supports serial/threads/processes executors, got "
                f"{self.cfg.executor!r} (the ranks driver is a whole-run "
                f"backend)")
        self.tree = ContextTree()          # creation-order (stable) ids
        self.planes: list[bytes] = []      # encoded canonical CSR, stable ids
        self.traces: list[tuple[np.ndarray, np.ndarray] | None] = []
        self.trace_lens: list[int] = []
        self.identities: list[dict | None] = []
        self.registries: list[list] = []
        self.nvals: list[int] = []
        self.stats_chain = StreamingReducer(_merge_stats)
        self.n_profiles = 0
        self.timings: dict[str, float] = {}

    @property
    def n_contexts(self) -> int:
        return len(self.tree)

    # -- the append (phase boundary) -----------------------------------------
    def append(self, profile_paths: list[str]) -> dict:
        """Merge one batch of profiles into the resident state.

        All-or-nothing: results are buffered per batch and committed only
        after the whole stream succeeds; on failure the unified tree is
        rolled back to its pre-batch length, so a poison profile rejects
        its batch without corrupting the state or future parity.
        """
        cfg = self.cfg
        n = len(profile_paths)
        if n == 0:
            return {"appended": 0, "n_contexts": self.n_contexts}
        timer = _PhaseTimer()
        t_start = time.perf_counter()
        n0_nodes = len(self.tree)
        try:
            with get_executor(cfg.executor, cfg.workers) as ex:
                batch = self._append_stream(profile_paths, timer, ex)
        except BaseException:
            self._rollback_tree(n0_nodes)
            raise
        # commit — stable-id results only reference nodes that now exist
        planes, traces, accs, idents, regs, tlens, nvals = batch
        self.planes.extend(planes)
        self.traces.extend(traces)
        self.identities.extend(idents)
        self.registries.extend(regs)
        self.trace_lens.extend(int(x) for x in tlens)
        self.nvals.extend(nvals)
        for acc in accs:  # global push order = profile arrival order
            self.stats_chain.push(acc)
        self.n_profiles += n
        for k, v in timer.acc.items():
            self.timings[k] = self.timings.get(k, 0.0) + v
        return {"appended": n, "n_profiles": self.n_profiles,
                "n_contexts": self.n_contexts,
                "append_s": time.perf_counter() - t_start}

    def _append_stream(self, profile_paths: list[str], timer: _PhaseTimer,
                       ex) -> tuple:
        cfg = self.cfg
        n = len(profile_paths)
        # phase 1 grows the shared tree in place; the sharded backend still
        # unifies in-process (the resident tree cannot live in pool workers)
        phase1_ex = ex if ex.in_process else get_executor(
            "threads", cfg.workers)
        _, remaps, routes, identities, trace_lens, registries = (
            phase1_unify_inprocess(profile_paths, timer, unified=self.tree,
                                   executor=phase1_ex))
        # this batch's canonical preorder — the coordinate system the fused
        # kernel runs in; order_a maps it back to stable creation ids
        pos_a, order_a, end_a = self.tree.preorder()
        arr_tree = _renumber(self.tree, pos_a, order_a)
        parent_pre = np.asarray(arr_tree.parent, dtype=np.int64)
        order_a = np.asarray(order_a, dtype=np.int64)

        planes: list[bytes | None] = [None] * n
        traces: list[tuple[np.ndarray, np.ndarray] | None] = [None] * n
        accs: list[StatsAccumulator | None] = [None] * n
        nvals: list[int] = [0] * n

        def consume(i: int, payload, p_ctx: int, p_vals: int, acc) -> None:
            # the slab payload is recycled when we return: decode, relabel
            # batch-preorder -> stable, and keep our own encoded copy
            sm, _ = SparseMetrics.decode(payload)
            planes[i] = relabel_plane(sm, order_a).encode()
            accs[i] = StatsAccumulator.from_arrays(
                _relabel_stat_arrays(acc.to_arrays(), order_a))
            nvals[i] = int(p_vals)

        trace_sink = None
        if cfg.write_traces:
            def trace_sink(i: int, tr: Trace) -> None:
                traces[i] = (np.array(tr.time, np.float64, copy=True),
                             order_a[tr.ctx.astype(np.int64)]
                             .astype(CTX_DTYPE))

        if ex.in_process:
            phase2_stream_inprocess(
                profile_paths,
                lambda i: pos_a[np.asarray(remaps[i], dtype=np.int64)],
                lambda i: {int(pos_a[ph]): (pos_a[t_], w)
                           for ph, (t_, w) in routes[i].items()},
                cfg, ex, parent_pre, end_a, timer, consume, trace_sink)
        else:
            remaps_final = [pos_a[np.asarray(remaps[i], dtype=np.int64)]
                            for i in range(n)]
            routes_final = [
                {int(pos_a[ph]): (pos_a[np.asarray(t_, np.int64)], w)
                 for ph, (t_, w) in routes[i].items()}
                for i in range(n)
            ]
            phase2_stream_sharded(profile_paths, remaps_final, routes_final,
                                  cfg, ex, parent_pre, end_a, timer, consume,
                                  trace_sink)
        return planes, traces, accs, identities, registries, trace_lens, nvals

    def _rollback_tree(self, n0: int) -> None:
        """Drop nodes a failed batch added.  Interned names may linger in
        the tree's name table — harmless: publication re-interns only the
        names reachable from surviving nodes."""
        del self.tree.parent[n0:]
        del self.tree.kind[n0:]
        del self.tree.name_id[n0:]
        self.tree._children = {
            k: c for k, c in self.tree._children.items() if c < n0}

    # -- publication ---------------------------------------------------------
    def write_database(self, out_dir) -> dict:
        """Write a complete PMS (+CMS, +traces) database of everything
        appended so far into ``out_dir`` — the payload of one snapshot
        epoch.  Resident state is untouched; appends may continue after.
        """
        cfg = self.cfg
        out_dir = str(out_dir)
        os.makedirs(out_dir, exist_ok=True)
        t0 = time.perf_counter()
        n = self.n_profiles
        pos, order, _end = self.tree.preorder()
        final_tree = _renumber(self.tree, pos, order)
        pos = np.asarray(pos, dtype=np.int64)

        # planes: relabel stable -> final preorder; sequential profile-order
        # add_plane reproduces the one-shot two-buffer layout byte for byte
        # (both allocate contiguously from the same atomic cursor)
        pms_path = os.path.join(out_dir, "db.pms")
        pms = PMSWriter(pms_path, n)
        try:
            for i in range(n):
                sm, _ = SparseMetrics.decode(self.planes[i])
                pms.add_plane(i, relabel_plane(sm, pos), self.identities[i])

            trace_path = None
            if cfg.write_traces and sum(self.trace_lens) > 0:
                trace_path = os.path.join(out_dir, "db.trc")
                tw = TraceDBWriter(trace_path, list(self.trace_lens))
                try:
                    for i, stored in enumerate(self.traces):
                        if stored is not None:
                            ttime, sctx = stored
                            tw.write_trace(i, Trace(
                                ttime,
                                pos[sctx.astype(np.int64)].astype(CTX_DTYPE)))
                finally:
                    tw.close()

            snap = _snapshot_reduce(self.stats_chain) or StatsAccumulator()
            final_acc = StatsAccumulator()
            final_acc.merge(StatsAccumulator.from_arrays(
                _relabel_stat_arrays(snap.to_arrays(), pos)))
            stats = final_acc.finalize()
            registry_json = next((r for r in self.registries if r), [])
            pms_bytes = pms.finalize(
                tree=final_tree, registry_json=registry_json,
                stats={k: np.asarray(v, np.float64)
                       for k, v in stats.items()})
        except BaseException:
            pms.abort()
            raise

        cms_bytes = 0
        if cfg.write_cms:
            cms_bytes = cms_mod.build_cms(
                pms_path, os.path.join(out_dir, "db.cms"),
                n_workers=cfg.cms_workers, strategy=cfg.cms_strategy,
                balance=cfg.cms_balance,
                group_target_bytes=cfg.group_target_bytes,
                executor=cfg.executor)
        return {"n_profiles": n, "n_contexts": len(final_tree),
                "n_values": int(sum(self.nvals)),
                "pms_bytes": pms_bytes, "cms_bytes": cms_bytes,
                "write_s": time.perf_counter() - t0}
