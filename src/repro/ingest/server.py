"""HTTP upload endpoint for the live ingest tier.

One :class:`IngestHTTPServer` owns a snapshot root::

    root/
      spool/              accepted-but-unmerged uploads (crash-safe queue;
                          each entry's filename carries a crc32 of its
                          bytes, verified on restart recovery)
      spool/quarantine/   entries whose checksum failed recovery (torn
                          writes / bit rot), kept for inspection
      epoch-NNNNNNNNNN/   published snapshots (repro.ingest.snapshot)
      CURRENT             atomic pointer to the newest epoch

Uploads land in the spool from connection threads; a single **merger
thread** drains them through :meth:`~repro.ingest.state.IngestState.append`
— the incremental phase-2 pipeline — so aggregation order is the arrival
order and the resident state is only ever touched by one thread.  A
publish (explicit ``POST /v1/publish`` or automatic every
``publish_every`` profiles) writes the state as a fresh epoch through
:class:`~repro.ingest.snapshot.SnapshotStore` and GCs old epochs past the
retention bound.  Followers (``query-server --follow``) pick the new
epoch up from ``CURRENT`` without restart.

Endpoints::

    POST /v1/ingest   application/octet-stream: one RPRF profile blob
                      application/json: {"profiles": ["<b64 rprf>", ...]}
                      -> 200 {"accepted": k, "pending": n}
                      -> 400 not RPRF / malformed envelope
                      -> 413 body over max_body_bytes
                      -> 429 + Retry-After when the spool backlog is full
    POST /v1/publish  drain the spool, write a snapshot, GC old epochs
                      -> 200 {"epoch": N, "dir": ..., "stats": {...}}
    GET  /v1/epochs   {"current": N, "epochs": [...], "pinned": [...]}
    GET  /healthz     liveness + resident-state size
    GET  /metrics     ingest/merge/publish counters and latency histograms

Error codes mirror the query transport (:mod:`repro.serve.http`), so one
:class:`~repro.serve.client.RetryPolicy` drives clients of both services.
"""
from __future__ import annotations

import base64
import json
import math
import os
import queue as queue_mod
import threading
import time
import zlib
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from repro.core.sparse import PROFILE_MAGIC
from repro.ingest.snapshot import SnapshotStore
from repro.ingest.state import IngestState
from repro.obs import MetricsRegistry, monotime, recorder, valid_trace_id
from repro.serve.scheduler import Overloaded

MAX_BODY_BYTES = 64 << 20
SPOOL_DIR = "spool"
QUARANTINE_DIR = "quarantine"  # under spool/: corrupt entries land here


def spool_entry_name(seq: int, blob: bytes) -> str:
    """Spool filename carrying its own integrity check:
    ``NNNNNNNNNNNN.<crc32 hex>.rprf``.  The crc is of the blob as
    written, so a restart can detect torn/bit-rotted entries without
    parsing them."""
    return f"{seq:012d}.{zlib.crc32(blob) & 0xFFFFFFFF:08x}.rprf"


def spool_entry_ok(path: str, name: str) -> bool:
    """Verify one recovered spool entry.  Checksummed names must match
    their crc; legacy names (``NNNNNNNNNNNN.rprf``, written before
    checksumming) are accepted iff the content still looks like an RPRF
    blob — the strongest check available for them."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return False
    parts = name.split(".")
    if len(parts) == 3:  # seq.crc.rprf
        try:
            want = int(parts[1], 16)
        except ValueError:
            return False
        return (zlib.crc32(data) & 0xFFFFFFFF) == want
    return data.startswith(PROFILE_MAGIC)


class _BadUpload(ValueError):
    pass


class _TooLarge(ValueError):
    pass


class IngestHTTPServer:
    """Continuous profile uploads -> incremental aggregation -> snapshots.

    ``max_pending`` bounds the spool backlog (admission control: beyond it
    uploads get 429 with a ``Retry-After`` derived from the observed merge
    rate); ``publish_every`` > 0 publishes a snapshot automatically each
    time that many new profiles have merged; ``retain`` epochs are kept by
    the post-publish GC (plus the current epoch and any pinned ones).

    :meth:`pause`/:meth:`resume` freeze the merger between batches —
    deterministic backpressure for tests and maintenance windows.
    """

    def __init__(self, root, *, host: str = "127.0.0.1", port: int = 0,
                 config=None, max_body_bytes: int = MAX_BODY_BYTES,
                 max_pending: int = 256, merge_batch: int = 32,
                 publish_every: int = 0, retain: int = 2):
        self.root = str(root)
        self.store = SnapshotStore(self.root)
        self.state = IngestState(config=config)
        self.max_body_bytes = int(max_body_bytes)
        self.max_pending = max(1, int(max_pending))
        self.merge_batch = max(1, int(merge_batch))
        self.publish_every = max(0, int(publish_every))
        self.retain = max(1, int(retain))
        self.host, self._port = host, int(port)

        self._spool = os.path.join(self.root, SPOOL_DIR)
        os.makedirs(self._spool, exist_ok=True)
        self._queue: queue_mod.Queue = queue_mod.Queue()
        self._lock = threading.Lock()          # counters + spool seq
        self._state_lock = threading.Lock()    # resident IngestState
        self._seq = 0
        self._pending = 0                      # spooled, not yet merged
        self._merging = False
        self._paused = threading.Event()
        self._stop = threading.Event()
        self._merger: threading.Thread | None = None
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        self._started_t = 0.0
        self._last_pub_profiles = 0
        # counters + histograms on one obs registry: the JSON /metrics view
        # reads them as before, GET /metrics?format=prom renders the same
        # instruments as Prometheus text exposition
        self.obs = MetricsRegistry()
        self._merge_hist = self.obs.histogram("ingest.merge_latency")
        self._publish_hist = self.obs.histogram("ingest.publish_latency")
        self._counters = self.obs.group(
            "ingest", {"http_requests": 0, "profiles_ingested": 0,
                       "bytes_ingested": 0, "profiles_merged": 0,
                       "merges": 0, "merge_failures": 0,
                       "epochs_published": 0, "gc_removed": 0,
                       "rejected_overload": 0, "rejected_bad": 0,
                       "spool_quarantined": 0})
        self.obs.gauge("ingest.pending", lambda: self._pending)
        self.obs.gauge("ingest.paused", lambda: self._paused.is_set())
        self.obs.gauge("ingest.resident_profiles",
                       lambda: self.state.n_profiles)
        self.obs.gauge("ingest.resident_contexts",
                       lambda: len(self.state.tree.parent))
        self.obs.gauge("ingest.uptime_s",
                       lambda: monotime() - self._started_t)
        self._last_merge_error: str | None = None
        self._draining = False

        # recover a spool left behind by a crash: verify each entry's
        # checksum and re-enqueue the good ones in seq order; corrupt
        # entries (torn writes, bit rot) go to spool/quarantine/ for
        # inspection instead of poisoning a merge batch
        self._quarantine_dir = os.path.join(self._spool, QUARANTINE_DIR)
        for name in sorted(os.listdir(self._spool)):
            if not name.endswith(".rprf"):
                continue
            path = os.path.join(self._spool, name)
            try:
                self._seq = max(self._seq,
                                int(name.split(".", 1)[0], 10) + 1)
            except ValueError:
                self._quarantine(path, name)
                continue
            if spool_entry_ok(path, name):
                self._queue.put(path)
                self._pending += 1
            else:
                self._quarantine(path, name)

    def _quarantine(self, path: str, name: str) -> None:
        os.makedirs(self._quarantine_dir, exist_ok=True)
        try:
            os.replace(path, os.path.join(self._quarantine_dir, name))
        except OSError:
            return
        self._counters["spool_quarantined"] += 1

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "IngestHTTPServer":
        if self._httpd is not None:
            return self
        self._merger = threading.Thread(target=self._merge_loop, daemon=True,
                                        name="ingest-merger")
        self._merger.start()
        service = self

        class Handler(_IngestHandler):
            pass

        Handler.service = service
        self._httpd = ThreadingHTTPServer((self.host, self._port), Handler)
        self._httpd.daemon_threads = True
        self._started_t = monotime()
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        kwargs={"poll_interval": 0.1},
                                        daemon=True, name="ingest-http")
        self._thread.start()
        return self

    def drain(self, timeout_s: float = 10.0) -> dict:
        """Graceful shutdown, phase one: new uploads are shed with
        ``503 {"error": "Draining"}`` while the merger keeps folding the
        spooled backlog for up to ``timeout_s``.  Anything still spooled
        at the deadline is safe — spool entries are durable and recovered
        (checksum-verified) on the next start.  Follow with :meth:`stop`.
        """
        self._draining = True
        t0 = monotime()
        deadline = t0 + max(0.0, float(timeout_s))
        drained = False
        while monotime() < deadline:
            with self._lock:
                if self._pending == 0 and not self._merging:
                    drained = True
                    break
            if self._paused.is_set():
                break  # a paused merger will never drain; don't spin
            time.sleep(0.02)
        return {"drained": drained, "pending": self._pending,
                "waited_s": round(monotime() - t0, 3)}

    def stop(self) -> None:
        self._stop.set()
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        if self._merger is not None:
            self._merger.join(timeout=10.0)
            self._merger = None

    @property
    def address(self) -> tuple[str, int]:
        assert self._httpd is not None, "server not started"
        return self._httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def __enter__(self) -> "IngestHTTPServer":
        return self.start()

    def __exit__(self, *a) -> None:
        self.stop()

    # -- merger control -------------------------------------------------------
    def pause(self) -> None:
        """Freeze the merger between batches (uploads keep spooling until
        the backlog hits ``max_pending`` and 429s start)."""
        self._paused.set()

    def resume(self) -> None:
        self._paused.clear()

    # -- upload admission -----------------------------------------------------
    def enqueue(self, blobs: list[bytes]) -> dict:
        """Validate, spool, and queue uploaded profile blobs."""
        for b in blobs:
            if not b.startswith(PROFILE_MAGIC):
                raise _BadUpload("not an RPRF profile blob")
        with self._lock:
            if self._pending + len(blobs) > self.max_pending:
                self._counters["rejected_overload"] += 1
                # hint scaled by how long a merge batch takes to drain
                hint = max(0.05, self._merge_hist.quantile(0.5) or 0.1)
                raise Overloaded(retry_after_s=hint)
            paths = []
            for b in blobs:
                path = os.path.join(self._spool,
                                    spool_entry_name(self._seq, b))
                self._seq += 1
                paths.append((path, b))
            self._pending += len(blobs)
            self._counters["profiles_ingested"] += len(blobs)
            self._counters["bytes_ingested"] += sum(len(b) for b in blobs)
            pending = self._pending
        for path, b in paths:
            with open(path, "wb") as f:
                f.write(b)
            self._queue.put(path)
        return {"accepted": len(blobs), "pending": pending}

    # -- merger ---------------------------------------------------------------
    def _merge_loop(self) -> None:
        while not self._stop.is_set():
            if self._paused.is_set():
                time.sleep(0.01)
                continue
            try:
                first = self._queue.get(timeout=0.1)
            except queue_mod.Empty:
                continue
            # a pause() may land while we were blocked in get(): hold the
            # dequeued item (still counted as pending) instead of merging
            # it, so pause really freezes the state between batches
            while self._paused.is_set() and not self._stop.is_set():
                time.sleep(0.01)
            if self._stop.is_set():
                break  # still spooled on disk; recovered on restart
            batch = [first]
            while len(batch) < self.merge_batch:
                try:
                    batch.append(self._queue.get_nowait())
                except queue_mod.Empty:
                    break
            with self._lock:
                self._merging = True
            try:
                t0 = monotime()
                with self._state_lock:
                    self.state.append(batch)
                self._merge_hist.observe(monotime() - t0)
                for path in batch:
                    try:
                        os.unlink(path)
                    except OSError:
                        pass
                with self._lock:
                    self._counters["merges"] += 1
                    self._counters["profiles_merged"] += len(batch)
            except Exception as e:                          # noqa: BLE001
                # append() is all-or-nothing: state is unchanged; drop the
                # poisoned batch so one corrupt blob cannot wedge ingest
                with self._lock:
                    self._counters["merge_failures"] += 1
                self._last_merge_error = f"{type(e).__name__}: {e}"
                for path in batch:
                    try:
                        os.unlink(path)
                    except OSError:
                        pass
            finally:
                with self._lock:
                    self._pending -= len(batch)
                    self._merging = False
            if (self.publish_every
                    and (self.state.n_profiles - self._last_pub_profiles
                         >= self.publish_every)):
                try:
                    self._do_publish()
                except Exception as e:                      # noqa: BLE001
                    self._last_merge_error = (
                        f"auto-publish: {type(e).__name__}: {e}")

    def _drain(self, timeout_s: float) -> None:
        deadline = monotime() + float(timeout_s)
        while True:
            with self._lock:
                if self._pending == 0 and not self._merging:
                    return
                stuck = self._paused.is_set() and self._pending > 0
            if stuck:
                raise RuntimeError("merger is paused with uploads pending; "
                                   "resume() before publishing")
            if monotime() > deadline:
                raise TimeoutError(
                    f"spool did not drain within {timeout_s:.0f}s")
            time.sleep(0.01)

    # -- publish --------------------------------------------------------------
    def _do_publish(self) -> dict:
        with self._state_lock:
            if self.state.n_profiles == 0:
                raise ValueError("nothing to publish: no profiles ingested")
            t0 = monotime()
            stats_box = {}

            def write(stage: str) -> None:
                stats_box.update(self.state.write_database(stage))

            epoch, final_dir = self.store.publish(
                write, extra_meta={"n_profiles": self.state.n_profiles})
            self._last_pub_profiles = self.state.n_profiles
        removed = self.store.gc(retain=self.retain)
        dt = monotime() - t0
        self._publish_hist.observe(dt)
        with self._lock:
            self._counters["epochs_published"] += 1
            self._counters["gc_removed"] += len(removed)
        return {"epoch": epoch, "dir": final_dir, "seconds": round(dt, 4),
                "gc_removed": removed, "stats": stats_box}

    def publish(self, *, timeout_s: float = 120.0) -> dict:
        """Drain the spool, then snapshot the resident state as the next
        epoch and GC old ones.  Blocks until the snapshot is durable."""
        self._drain(timeout_s)
        return self._do_publish()

    # -- introspection --------------------------------------------------------
    def health(self) -> dict:
        cur = self.store.current()
        return {"status": "ok",
                "profiles": self.state.n_profiles,
                "contexts": len(self.state.tree.parent),
                "pending": self._pending,
                "paused": self._paused.is_set(),
                "draining": self._draining,
                "epoch": cur[0] if cur else None,
                "uptime_s": round(monotime() - self._started_t, 3)}

    def epochs(self) -> dict:
        cur = self.store.current()
        return {"current": cur[0] if cur else None,
                "epochs": self.store.epochs(),
                "pinned": self.store.pinned_epochs()}

    def metrics(self) -> dict:
        with self._lock:
            out = dict(self._counters)
        out.update({"pending": self._pending,
                    "paused": self._paused.is_set(),
                    "resident_profiles": self.state.n_profiles,
                    "resident_contexts": len(self.state.tree.parent),
                    "merge_latency": self._merge_hist.as_dict(),
                    "publish_latency": self._publish_hist.as_dict(),
                    "last_merge_error": self._last_merge_error,
                    "epochs": self.store.epochs(),
                    "uptime_s": round(monotime() - self._started_t, 3)})
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition of every ingest instrument."""
        return MetricsRegistry.render([self.obs])

    # -- request bodies -------------------------------------------------------
    def ingest_call(self, body: bytes, content_type: str,
                    trace_id: str | None = None) -> dict:
        """Decode one upload body and spool it.  A JSON envelope may carry
        a ``trace_id`` (same contract as the query transport's
        ``X-Trace-Id`` header, which also lands here) — the accept path
        records an ``ingest`` span under that id."""
        tid = trace_id if trace_id and valid_trace_id(trace_id) else ""
        if content_type.startswith("application/json"):
            try:
                obj = json.loads(body.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise _BadUpload(f"malformed JSON envelope: {e}") from None
            raw = obj.get("profiles") if isinstance(obj, dict) else None
            if not isinstance(raw, list) or not raw:
                raise _BadUpload("body needs a non-empty 'profiles' list")
            env_tid = obj.get("trace_id")
            if not tid and isinstance(env_tid, str) and valid_trace_id(env_tid):
                tid = env_tid
            try:
                blobs = [base64.b64decode(s) for s in raw]
            except (TypeError, ValueError) as e:
                raise _BadUpload(f"profiles must be base64: {e}") from None
        else:
            blobs = [body]
        rec = recorder()
        t0 = monotime() if rec.enabled else 0.0
        out = self.enqueue(blobs)
        if rec.enabled:
            rec.record("ingest", "upload", t0, monotime() - t0,
                       trace_id=tid, attrs={"profiles": len(blobs)})
        if tid:
            out["trace_id"] = tid
        return out


class _IngestHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "repro-ingest/1.0"
    service: IngestHTTPServer  # injected per server instance

    def log_message(self, fmt, *args):  # noqa: A003 - stdlib signature
        pass

    def _send_json(self, code: int, obj: dict,
                   extra_headers: dict | None = None) -> None:
        payload = json.dumps(obj).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(payload)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(payload)

    def do_GET(self):  # noqa: N802 - stdlib casing
        svc = self.service
        parts = urlsplit(self.path)
        if parts.path == "/healthz":
            self._send_json(200, svc.health())
        elif parts.path == "/metrics":
            q = parse_qs(parts.query)
            if q.get("format", [""])[0] == "prom":
                payload = svc.prometheus().encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)
            else:
                self._send_json(200, svc.metrics())
        elif parts.path == "/v1/epochs":
            self._send_json(200, svc.epochs())
        else:
            self._send_json(404, {"error": "NotFound", "path": self.path})

    def do_POST(self):  # noqa: N802 - stdlib casing
        svc = self.service
        if svc._draining:
            # structured shed: the spool stays durable, the uploader's
            # RetryPolicy moves to another instance or retries later
            self.close_connection = True
            self._send_json(503, {"error": "Draining",
                                  "message": "ingest endpoint is draining"},
                            {"Retry-After": "1", "Connection": "close"})
            return
        svc._counters["http_requests"] += 1
        try:
            if self.path == "/v1/ingest":
                try:
                    n = int(self.headers.get("Content-Length", 0))
                except ValueError:
                    n = -1
                if n > svc.max_body_bytes:
                    # never read: drop the connection so the keep-alive
                    # stream cannot desynchronize on the unread bytes
                    self.close_connection = True
                    raise _TooLarge(f"body of {n} bytes exceeds "
                                    f"{svc.max_body_bytes}")
                if n <= 0:
                    self.close_connection = True
                    raise _BadUpload("Content-Length required and positive")
                body = self.rfile.read(n)
                ctype = self.headers.get("Content-Type",
                                         "application/octet-stream")
                self._send_json(200, svc.ingest_call(
                    body, ctype, trace_id=self.headers.get("X-Trace-Id")))
            elif self.path == "/v1/publish":
                # drain any (small) body so the keep-alive stream stays
                # aligned for the next request on this connection
                try:
                    n = int(self.headers.get("Content-Length", 0))
                except ValueError:
                    n = 0
                if n > 4096:
                    self.close_connection = True
                    raise _BadUpload("publish takes no body")
                if n > 0:
                    self.rfile.read(n)
                self._send_json(200, svc.publish())
            else:
                self._send_json(404, {"error": "NotFound", "path": self.path})
        except _TooLarge as e:
            self._send_json(413, {"error": "TooLarge", "message": str(e)})
        except (_BadUpload, ValueError) as e:
            self._send_json(400, {"error": "BadRequest", "message": str(e)})
        except Overloaded as e:
            self._send_json(
                429, {"error": "Overloaded",
                      "retry_after_s": e.retry_after_s},
                {"Retry-After": str(max(1, math.ceil(e.retry_after_s)))})
        except Exception as e:  # noqa: BLE001 - last-resort 500
            self._send_json(500, {"error": type(e).__name__,
                                  "message": str(e)})
