"""Lexical context expansion & context reconstruction (paper §4.1.1, §4.1.3).

HPCToolkit expands raw instruction offsets with lexical scopes (inlined
functions, loops, lines) parsed from DWARF/hpcstruct.  Our measured
artifact is a compiled XLA module, so the analog "structure file" maps HLO
op names to their enclosing lexical scopes — the name-scope/module path
recorded by :mod:`repro.profiling.hlo_attrib` when the program was lowered.

Reconstruction: an XLA *fusion* op loses provenance exactly the way flat
GPU PC samples do — one measured op corresponds to several source modules.
A structure entry may therefore carry several weighted "routes"; costs
measured on such an op are attributed to a placeholder context "in
superposition" and redistributed across the route leaves before inclusive
propagation (paper §4.1.3), via
:func:`repro.core.propagate.redistribute_placeholders`.

Structure file (JSON)::

    {"binary": "<module fingerprint>",
     "ops": {"<op name>": [ {"path": [[kind, name], ...], "weight": w}, ...]}}
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from repro.core.cct import KIND_OP, KIND_ROUTE, ContextTree


@dataclass
class StructureInfo:
    binary: str
    ops: dict[str, list[dict]] = field(default_factory=dict)

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump({"binary": self.binary, "ops": self.ops}, f)

    @classmethod
    def load(cls, path) -> "StructureInfo":
        with open(path) as f:
            d = json.load(f)
        return cls(d["binary"], d["ops"])

    def add_op(self, op: str, path: list[tuple[int, str]], weight: float = 1.0) -> None:
        self.ops.setdefault(op, []).append(
            {"path": [[int(k), str(n)] for k, n in path], "weight": float(weight)}
        )


def expand_profile_tree(
    unified: ContextTree,
    local: ContextTree,
    structures: dict[str, StructureInfo],
) -> tuple[np.ndarray, dict[int, tuple[np.ndarray, np.ndarray]]]:
    """The "edit" + "U" composition of paper Fig. 3 for one profile.

    Maps every local context onto the unified tree, inserting lexical
    scopes as parents of op contexts.  Returns ``(remap, routes)``:
    ``remap[local_id] -> unified_id`` and, for multi-route (reconstructed)
    ops, ``routes[placeholder_unified_id] = (leaf_ids, weights)``.
    """
    # merge per-binary op tables ("eagerly acquire lexical information")
    op_table: dict[str, list[dict]] = {}
    for s in structures.values():
        for op, routes in s.ops.items():
            op_table.setdefault(op, []).extend(routes)

    remap = np.zeros(len(local), dtype=np.uint32)
    routes_out: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    for cid in range(1, len(local)):
        parent_u = int(remap[local.parent[cid]])
        kind = local.kind[cid]
        name = local.name_of(cid)
        if kind == KIND_OP and name in op_table:
            entries = op_table[name]
            leaf_ids = []
            weights = []
            for e in entries:
                node = unified.path([(int(k), n) for k, n in e["path"]], parent_u)
                leaf_ids.append(unified.child(node, KIND_OP, name))
                weights.append(e["weight"])
            if len(leaf_ids) == 1:
                remap[cid] = leaf_ids[0]
            else:
                # superposition placeholder (paper §4.1.3)
                ph = unified.child(parent_u, KIND_ROUTE, name + "@superposition")
                remap[cid] = ph
                routes_out[ph] = (np.asarray(leaf_ids, dtype=np.int64),
                                  np.asarray(weights, dtype=np.float64))
        else:
            remap[cid] = unified.child(parent_u, kind, name)
    return remap, routes_out
