"""The sparse measurement format (paper §3.1, Fig. 1).

A profile's metric payload is two vectors:

* ``(metric, value)`` pairs ordered by context — ``mid: u16``, ``val: f64``;
* ``(context, index)`` pairs — ``ctx: u32``, ``start: u64`` — where ``start``
  is the index of the context's first metric/value pair.  A final sentinel
  pair marks the end of the last context's span (the paper's "last
  context/index pair").

Space: ``O(2(x + c + 1))`` words for ``x`` non-zeros over ``c`` non-empty
contexts.  Access: binary search over contexts then metrics —
``O(log c + log x_c)``.

:class:`MeasurementProfile` is the full per-worker profile file (paper §4.1's
six sections: environment, identity, file paths, contexts, trace, metrics).
"""
from __future__ import annotations

import io
import mmap
import struct
from dataclasses import dataclass, field

import numpy as np

from repro.utils import binio
from repro.core.cct import ContextTree

CTX_DTYPE = np.uint32
MID_DTYPE = np.uint16
VAL_DTYPE = np.float64
IDX_DTYPE = np.uint64

PROFILE_MAGIC = b"RPRF"


@dataclass
class SparseMetrics:
    """CSR-like (context -> [(metric, value)...]) block, Fig. 1 of the paper."""

    ctx: np.ndarray    # (c,) u32, strictly increasing non-empty context ids
    start: np.ndarray  # (c+1,) u64, start[k] = first pair index of ctx[k]
    mid: np.ndarray    # (x,) u16, metric ids (sorted within a context)
    val: np.ndarray    # (x,) f64, non-zero values

    # -- constructors -------------------------------------------------------
    @classmethod
    def empty(cls) -> "SparseMetrics":
        return cls(
            np.empty(0, CTX_DTYPE), np.zeros(1, IDX_DTYPE),
            np.empty(0, MID_DTYPE), np.empty(0, VAL_DTYPE),
        )

    @classmethod
    def from_triplets(cls, ctx_ids, mids, vals, *, combine: str = "sum") -> "SparseMetrics":
        """Build from unordered (ctx, metric, value) triplets.

        Duplicate (ctx, metric) keys are combined (summed); zero values are
        dropped — the format stores only non-zeros.
        """
        ctx_ids = np.asarray(ctx_ids, dtype=np.int64)
        mids = np.asarray(mids, dtype=np.int64)
        vals = np.asarray(vals, dtype=VAL_DTYPE)
        if ctx_ids.size == 0:
            return cls.empty()
        key = ctx_ids * (1 << 16) + mids
        order = np.argsort(key, kind="stable")
        key, vals = key[order], vals[order]
        uniq, inv = np.unique(key, return_inverse=True)
        if combine == "sum":
            cvals = np.zeros(uniq.size, VAL_DTYPE)
            np.add.at(cvals, inv, vals)
        elif combine == "last":
            cvals = np.empty(uniq.size, VAL_DTYPE)
            cvals[inv] = vals
        else:
            raise ValueError(combine)
        keep = cvals != 0.0
        uniq, cvals = uniq[keep], cvals[keep]
        uctx = (uniq >> 16).astype(np.int64)
        umid = (uniq & 0xFFFF).astype(MID_DTYPE)
        # context boundaries
        bounds = np.flatnonzero(np.diff(uctx, prepend=-1))
        starts = np.concatenate([bounds, [uctx.size]]).astype(IDX_DTYPE)
        return cls(uctx[bounds].astype(CTX_DTYPE), starts, umid, cvals)

    @classmethod
    def from_dense(cls, mat: np.ndarray, ctx_ids: np.ndarray | None = None) -> "SparseMetrics":
        """From a dense (n_ctx x n_metrics) matrix (the HPCToolkit layout)."""
        r, c = np.nonzero(mat)
        rows = r if ctx_ids is None else np.asarray(ctx_ids)[r]
        return cls.from_triplets(rows, c, mat[r, c])

    # -- views ----------------------------------------------------------------
    @property
    def n_contexts(self) -> int:
        return int(self.ctx.size)

    @property
    def n_values(self) -> int:
        return int(self.val.size)

    def to_dense(self, n_ctx: int, n_metrics: int) -> np.ndarray:
        out = np.zeros((n_ctx, n_metrics), VAL_DTYPE)
        rows = np.repeat(self.ctx.astype(np.int64), np.diff(self.start.astype(np.int64)))
        out[rows, self.mid.astype(np.int64)] = self.val
        return out

    def triplets(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        rows = np.repeat(self.ctx.astype(np.int64), np.diff(self.start.astype(np.int64)))
        return rows, self.mid.astype(np.int64), self.val

    def context_slice(self, ctx_id: int) -> tuple[np.ndarray, np.ndarray]:
        """All (metric, value) pairs for one context; O(log c)."""
        k = int(np.searchsorted(self.ctx, ctx_id))
        if k >= self.ctx.size or self.ctx[k] != ctx_id:
            return np.empty(0, MID_DTYPE), np.empty(0, VAL_DTYPE)
        lo, hi = int(self.start[k]), int(self.start[k + 1])
        return self.mid[lo:hi], self.val[lo:hi]

    def lookup(self, ctx_id: int, mid: int) -> float:
        """Single value access: two binary searches (paper §3.1)."""
        mids, vals = self.context_slice(ctx_id)
        j = int(np.searchsorted(mids, mid))
        if j < mids.size and mids[j] == mid:
            return float(vals[j])
        return 0.0

    def remap_contexts(self, remap: np.ndarray) -> "SparseMetrics":
        rows, mids, vals = self.triplets()
        return SparseMetrics.from_triplets(np.asarray(remap)[rows], mids, vals)

    # -- sizes (evaluation currency of the paper) ----------------------------
    def nbytes(self) -> int:
        return self.ctx.nbytes + self.start.nbytes + self.mid.nbytes + self.val.nbytes

    @staticmethod
    def dense_nbytes(n_ctx: int, n_metrics: int) -> int:
        return n_ctx * n_metrics * np.dtype(VAL_DTYPE).itemsize

    # -- serialization ---------------------------------------------------------
    def encoded_nbytes(self) -> int:
        """Exact :meth:`encode` size — lets slab writers reserve space."""
        return sum(binio.packed_nbytes(a)
                   for a in (self.ctx, self.start, self.mid, self.val))

    def encode_into(self, view, off: int = 0) -> int:
        """Serialize directly into a writable buffer (shared-memory slab);
        byte-identical to :meth:`encode`.  Returns the new offset."""
        for a in (self.ctx, self.start, self.mid, self.val):
            off = binio.pack_array_into(view, off, a)
        return off

    def encode(self) -> bytes:
        out = bytearray(self.encoded_nbytes())
        self.encode_into(out, 0)
        return bytes(out)

    @classmethod
    def decode(cls, buf: bytes, off: int = 0) -> tuple["SparseMetrics", int]:
        ctx, off = binio.unpack_array(buf, off)
        start, off = binio.unpack_array(buf, off)
        mid, off = binio.unpack_array(buf, off)
        val, off = binio.unpack_array(buf, off)
        return cls(ctx, start, mid, val), off


@dataclass
class Trace:
    """Sample-based call-path trace: (timestamp, context) pairs (paper §4.1)."""

    time: np.ndarray  # (t,) f64 seconds
    ctx: np.ndarray   # (t,) u32 context ids

    @classmethod
    def empty(cls) -> "Trace":
        return cls(np.empty(0, VAL_DTYPE), np.empty(0, CTX_DTYPE))

    def nbytes(self) -> int:
        return self.time.nbytes + self.ctx.nbytes

    def remap_contexts(self, remap: np.ndarray) -> "Trace":
        return Trace(self.time, np.asarray(remap)[self.ctx.astype(np.int64)].astype(CTX_DTYPE))


@dataclass
class MeasurementProfile:
    """One per-worker profile file: the six sections of paper §4.1."""

    environment: dict = field(default_factory=dict)       # section 1
    identity: dict = field(default_factory=dict)          # section 2 (rank, stream, kind)
    file_paths: list = field(default_factory=list)        # section 3 ("binaries")
    tree: ContextTree = field(default_factory=ContextTree)  # section 4
    trace: Trace = field(default_factory=Trace.empty)     # section 5
    metrics: SparseMetrics = field(default_factory=SparseMetrics.empty)  # section 6

    def save(self, path) -> int:
        buf = io.BytesIO()
        buf.write(PROFILE_MAGIC + struct.pack("<I", 1))
        binio.write_json(buf, {
            "environment": self.environment,
            "identity": self.identity,
            "file_paths": self.file_paths,
        })
        for a in self.tree.to_arrays().values():
            binio.write_array(buf, a)
        binio.write_array(buf, self.trace.time)
        binio.write_array(buf, self.trace.ctx)
        buf.write(self.metrics.encode())
        data = buf.getvalue()
        with open(path, "wb") as f:
            f.write(data)
        return len(data)

    @classmethod
    def load(cls, path) -> "MeasurementProfile":
        """Zero-copy load: map the file and decode views over the mapping.

        Metric/trace arrays alias the page cache (via ``binio.unpack_array``
        views) until something copies them — phase 2 of the aggregator never
        does, so a profile is read from disk at most once with no private
        materialization.  The map stays alive for as long as any decoded
        array references it; falls back to a plain read for empty files and
        filesystems that refuse ``mmap``.
        """
        with open(path, "rb") as f:
            try:
                buf = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
            except (OSError, ValueError):
                buf = f.read()
        return cls.decode(buf)

    @classmethod
    def decode(cls, buf) -> "MeasurementProfile":
        assert bytes(buf[:4]) == PROFILE_MAGIC, "not a profile file"
        off = 8
        meta, off = binio.unpack_json(buf, off)
        arrs = {}
        for key in ("parent", "kind", "name_id", "names"):
            arrs[key], off = binio.unpack_array(buf, off)
        tree = ContextTree.from_arrays(arrs)
        ttime, off = binio.unpack_array(buf, off)
        tctx, off = binio.unpack_array(buf, off)
        metrics, off = SparseMetrics.decode(buf, off)
        return cls(meta["environment"], meta["identity"], meta["file_paths"],
                   tree, Trace(ttime, tctx), metrics)

    def nbytes(self) -> int:
        return self.metrics.nbytes() + self.trace.nbytes()
