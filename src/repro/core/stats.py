"""Cross-profile summary statistics (paper §4.1.2, §4.2.2).

For every (context, metric) the analysis accumulates statistics of the
non-zero costs observed across profiles: sum, count-of-nonzeros, min, max
and sum-of-squares, finalized into mean/std once the database "completes".

The paper uses per-context concurrent hash tables with relaxed-atomic FP
accumulators.  The TPU/data-parallel adaptation is *sorted segmented
reduction*: keys are packed ``ctx * 2^16 | mid`` (u64), partial updates are
buffered and lazily compacted with sort + ``reduceat`` — contention-free and
mergeable, so the same object implements the leaves and the internal nodes
of the process-level reduction tree (paper §4.4 phase 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.sparse import SparseMetrics

KEY_SHIFT = 16  # key = ctx << 16 | mid

# Key-packing domain, shared with repro.core.pipeline (which packs the same
# keys into *signed* int64 for its stable argsort):
#  - a raw (exclusive) metric id must stay below bit 15 — that bit is
#    repro.core.metrics.INCLUSIVE_BIT, and a mid >= 2^15 would silently
#    alias an exclusive metric onto an inclusive key;
#  - a packed mid (inclusive bit allowed) must fit the 16-bit field;
#  - a context id must keep ctx << 16 inside int64, or the pipeline's keys
#    wrap negative and the plane sorts/merges garbage.
MAX_RAW_MID = 1 << 15
MAX_PACKED_MID = 1 << 16
MAX_CTX = 1 << 47

_FIELDS = ("sum", "cnt", "vmin", "vmax", "sumsq")


def check_key_ranges(ctx, mid, *, packed: bool = False) -> None:
    """Validate ids before packing ``ctx << 16 | mid`` keys; raises
    ``ValueError`` instead of corrupting keys silently.  ``packed=True``
    admits mids carrying the inclusive bit (bit 15); the default rejects
    it — raw profile metric ids own only bits 0..14."""
    mid_limit = MAX_PACKED_MID if packed else MAX_RAW_MID
    if np.size(mid) and int(np.max(mid)) >= mid_limit:
        raise ValueError(
            f"metric id {int(np.max(mid))} >= {mid_limit}: "
            + ("mids must fit 16 bits"
               if packed else
               "bit 15 is reserved for INCLUSIVE_BIT — a raw metric id this "
               "large would alias an inclusive key"))
    if np.size(ctx) and int(np.max(ctx)) >= MAX_CTX:
        raise ValueError(
            f"context id {int(np.max(ctx))} >= 2^47: ctx << 16 would "
            f"overflow the signed 64-bit key space")


def pack_keys(ctx: np.ndarray, mid: np.ndarray) -> np.ndarray:
    check_key_ranges(ctx, mid, packed=True)
    return (np.asarray(ctx, np.uint64) << np.uint64(KEY_SHIFT)) | np.asarray(mid, np.uint64)


def unpack_keys(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    return (keys >> np.uint64(KEY_SHIFT)).astype(np.int64), (keys & np.uint64(0xFFFF)).astype(np.int64)


def _segment_reduce(keys, svals, cvals, mins, maxs, sqs):
    """Sort by key and reduce each segment; returns compacted arrays."""
    order = np.argsort(keys, kind="stable")
    keys = keys[order]
    svals, cvals = svals[order], cvals[order]
    mins, maxs, sqs = mins[order], maxs[order], sqs[order]
    bounds = np.flatnonzero(np.diff(keys.view(np.int64), prepend=-1))
    return (
        keys[bounds],
        np.add.reduceat(svals, bounds),
        np.add.reduceat(cvals, bounds),
        np.minimum.reduceat(mins, bounds),
        np.maximum.reduceat(maxs, bounds),
        np.add.reduceat(sqs, bounds),
    )


@dataclass
class StatsAccumulator:
    """Mergeable (ctx, metric) -> {sum, count, min, max, sumsq} accumulator."""

    keys: np.ndarray
    sum: np.ndarray
    cnt: np.ndarray
    vmin: np.ndarray
    vmax: np.ndarray
    sumsq: np.ndarray

    def __init__(self):
        self.keys = np.empty(0, np.uint64)
        self.sum = np.empty(0, np.float64)
        self.cnt = np.empty(0, np.float64)
        self.vmin = np.empty(0, np.float64)
        self.vmax = np.empty(0, np.float64)
        self.sumsq = np.empty(0, np.float64)
        self._buf: list[tuple[np.ndarray, np.ndarray]] = []
        self._buf_n = 0

    # -- streaming updates (the + op of paper Fig. 3) -----------------------
    def update(self, metrics: SparseMetrics) -> None:
        rows, mids, vals = metrics.triplets()
        if rows.size == 0:
            return
        self._buf.append((pack_keys(rows, mids), vals))
        self._buf_n += rows.size
        if self._buf_n >= 1 << 20:
            self._compact()

    def _compact(self) -> None:
        if not self._buf:
            return
        k = np.concatenate([self.keys] + [b[0] for b in self._buf])
        v = np.concatenate([np.zeros(self.keys.size)] + [b[1] for b in self._buf])
        # rows from the existing accumulator carry their already-reduced
        # fields; fresh rows contribute (v, 1, v, v, v^2).
        n0 = self.keys.size
        s = np.concatenate([self.sum, v[n0:]])
        c = np.concatenate([self.cnt, np.ones(v.size - n0)])
        mn = np.concatenate([self.vmin, v[n0:]])
        mx = np.concatenate([self.vmax, v[n0:]])
        sq = np.concatenate([self.sumsq, v[n0:] ** 2])
        self.keys, self.sum, self.cnt, self.vmin, self.vmax, self.sumsq = _segment_reduce(
            k, s, c, mn, mx, sq
        )
        self._buf, self._buf_n = [], 0

    # -- reduction-tree merge (paper §4.4) -----------------------------------
    def merge(self, other: "StatsAccumulator") -> None:
        other._compact()
        self._compact()
        k = np.concatenate([self.keys, other.keys])
        self.keys, self.sum, self.cnt, self.vmin, self.vmax, self.sumsq = _segment_reduce(
            k,
            np.concatenate([self.sum, other.sum]),
            np.concatenate([self.cnt, other.cnt]),
            np.concatenate([self.vmin, other.vmin]),
            np.concatenate([self.vmax, other.vmax]),
            np.concatenate([self.sumsq, other.sumsq]),
        )

    # -- completion ----------------------------------------------------------
    def finalize(self) -> dict[str, np.ndarray]:
        self._compact()
        ctx, mid = unpack_keys(self.keys)
        mean = np.divide(self.sum, self.cnt, out=np.zeros_like(self.sum), where=self.cnt > 0)
        var = np.maximum(self.sumsq / np.maximum(self.cnt, 1) - mean**2, 0.0)
        return {
            "ctx": ctx, "mid": mid,
            "sum": self.sum, "count": self.cnt, "mean": mean,
            "min": self.vmin, "max": self.vmax, "std": np.sqrt(var),
        }

    def __len__(self) -> int:
        self._compact()
        return int(self.keys.size)

    # -- (de)serialization for cross-process reduction trees ------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        self._compact()
        return {"keys": self.keys, "sum": self.sum, "cnt": self.cnt,
                "vmin": self.vmin, "vmax": self.vmax, "sumsq": self.sumsq}

    @classmethod
    def from_arrays(cls, arrs) -> "StatsAccumulator":
        acc = cls()
        acc.keys = np.asarray(arrs["keys"], np.uint64)
        acc.sum = np.asarray(arrs["sum"], np.float64)
        acc.cnt = np.asarray(arrs["cnt"], np.float64)
        acc.vmin = np.asarray(arrs["vmin"], np.float64)
        acc.vmax = np.asarray(arrs["vmax"], np.float64)
        acc.sumsq = np.asarray(arrs["sumsq"], np.float64)
        return acc
