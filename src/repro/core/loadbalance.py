"""Context-group partitioning for CMS output (paper §4.3.2, §4.4, Table 5).

Contexts are split into groups of *similar data size* (not similar count).
Two assignment schemes, compared in benchmark table5:

* **static** — groups pre-assigned contiguously to workers (the scheme the
  paper tried first and found imbalanced);
* **dynamic (GLB)** — workers pull the next group from a shared queue; the
  queue lock is the single-host analog of the paper's rank-0 "server"
  thread.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


def imbalance_ratio(vmax, mean) -> np.ndarray:
    """λ = max/mean, the classic load-imbalance metric, elementwise.

    λ == 1 is perfect balance; λ == N means one worker carried everything.
    Positions with mean <= 0 report 1.0 (an empty row is balanced, not
    infinite) so the caller can threshold without special-casing.
    """
    vmax = np.asarray(vmax, dtype=np.float64)
    mean = np.asarray(mean, dtype=np.float64)
    out = np.ones_like(vmax, dtype=np.float64)
    np.divide(vmax, mean, out=out, where=mean > 0)
    return out


def make_groups(sizes: np.ndarray, target_bytes: int) -> list[tuple[int, int]]:
    """Split contexts [0, n) into contiguous [lo, hi) groups of ~target_bytes.

    Contexts must stay contiguous and id-ordered so CMS offsets follow from
    an exclusive scan (paper §4.3.2).
    """
    sizes = np.asarray(sizes, dtype=np.int64)
    groups: list[tuple[int, int]] = []
    lo, acc = 0, 0
    for i, s in enumerate(sizes):
        acc += int(s)
        if acc >= target_bytes:
            groups.append((lo, i + 1))
            lo, acc = i + 1, 0
    if lo < sizes.size:
        groups.append((lo, sizes.size))
    if not groups:
        groups.append((0, 0))
    return groups


class StaticAssigner:
    """Pre-assign groups to workers contiguously by cumulative size."""

    def __init__(self, groups: list[tuple[int, int]], sizes: np.ndarray, n_workers: int):
        gsz = np.array([int(np.sum(sizes[lo:hi])) for lo, hi in groups], dtype=np.int64)
        csum = np.cumsum(gsz)
        total = int(csum[-1]) if gsz.size else 0
        self._assignment: list[list[tuple[int, int]]] = [[] for _ in range(n_workers)]
        for g, (lo, hi) in enumerate(groups):
            w = min(int((csum[g] - 1) * n_workers // max(total, 1)), n_workers - 1) if total else 0
            self._assignment[w].append((lo, hi))
        self._iters = [iter(a) for a in self._assignment]

    def next_group(self, worker: int):
        return next(self._iters[worker], None)


class DynamicAssigner:
    """GLB: shared queue of groups; the lock is the 'server thread' analog."""

    def __init__(self, groups: list[tuple[int, int]], sizes=None, n_workers: int = 1):
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        for g in groups:
            self._q.put(g)
        self._lock = threading.Lock()

    def next_group(self, worker: int):
        try:
            return self._q.get_nowait()
        except queue.Empty:
            return None


def make_assigner(kind: str, groups, sizes, n_workers):
    if kind == "static":
        return StaticAssigner(groups, sizes, n_workers)
    if kind == "dynamic":
        return DynamicAssigner(groups, sizes, n_workers)
    raise ValueError(kind)
