"""Calling-context trees (CCTs) and their preorder linearization.

HPCToolkit's unit of attribution is a calling-context-tree node.  In this
framework the analog is a node of the *program-structure tree* of a JAX
training/serving job:

    root -> phase (fwd/bwd/optimizer/data/...) -> module path (name scopes)
         -> op (HLO instruction group) -> line/route leaves

Identity of a node is ``(parent, kind, name)`` which makes cross-profile
unification (paper §4.1, the U operations) a pure tree merge.

The preorder linearization is the core TPU adaptation (DESIGN.md §4): after
ordering nodes in DFS preorder, every subtree occupies a contiguous interval
``[i, end[i])``, so the paper's recursive "propagate" walk (§4.1.2) becomes
``inclusive = prefix_sum[end[i]] - prefix_sum[i]`` — one streaming pass.
"""
from __future__ import annotations

import numpy as np

# Context kinds (the paper's: procedure / inlined function / loop / line /
# instruction; ours are the JAX-program analogs).
KIND_ROOT = 0
KIND_PHASE = 1    # fwd / bwd / optimizer / data / collective ...
KIND_MODULE = 2   # name-scope path component ("layers.3.attn")
KIND_LOOP = 3     # scan body / microbatch loop
KIND_OP = 4       # HLO op group ("dot_general", "all-reduce")
KIND_LINE = 5     # finest attribution unit (paper: source line)
KIND_ROUTE = 6    # reconstructed context route (paper §4.1.3)

KIND_NAMES = {
    KIND_ROOT: "root", KIND_PHASE: "phase", KIND_MODULE: "module",
    KIND_LOOP: "loop", KIND_OP: "op", KIND_LINE: "line", KIND_ROUTE: "route",
}


class ContextTree:
    """Growable CCT with (parent, kind, name)-keyed children.

    Node ids are assigned in creation order, so parents always precede
    children — ``merge`` and serialization rely on this invariant.
    """

    __slots__ = ("names", "_name_ids", "parent", "kind", "name_id", "_children")

    def __init__(self):
        self.names: list[str] = []
        self._name_ids: dict[str, int] = {}
        self.parent: list[int] = [-1]
        self.kind: list[int] = [KIND_ROOT]
        self.name_id: list[int] = [self._intern("<root>")]
        self._children: dict[tuple[int, int, int], int] = {}

    # -- construction -----------------------------------------------------
    def _intern(self, name: str) -> int:
        nid = self._name_ids.get(name)
        if nid is None:
            nid = len(self.names)
            self._name_ids[name] = nid
            self.names.append(name)
        return nid

    def child(self, parent: int, kind: int, name: str) -> int:
        """Get-or-create child — the uniquing (U) op of paper Fig. 3."""
        key = (parent, kind, self._intern(name))
        cid = self._children.get(key)
        if cid is None:
            cid = len(self.parent)
            self._children[key] = cid
            self.parent.append(parent)
            self.kind.append(kind)
            self.name_id.append(key[2])
        return cid

    def path(self, parts: list[tuple[int, str]], parent: int = 0) -> int:
        for kind, name in parts:
            parent = self.child(parent, kind, name)
        return parent

    def __len__(self) -> int:
        return len(self.parent)

    # -- queries ----------------------------------------------------------
    def name_of(self, cid: int) -> str:
        return self.names[self.name_id[cid]]

    def full_path(self, cid: int) -> str:
        parts = []
        while cid > 0:
            parts.append(self.name_of(cid))
            cid = self.parent[cid]
        return "/" + "/".join(reversed(parts))

    def parent_array(self) -> np.ndarray:
        return np.asarray(self.parent, dtype=np.int64)

    # -- unification ------------------------------------------------------
    def merge(self, other: "ContextTree") -> np.ndarray:
        """Merge ``other`` into self; returns remap st. new_id = remap[old_id].

        Walking in id order is sufficient because parents precede children.
        This is the reduction-tree merge payload of paper §4.4 phase 1.
        """
        remap = np.empty(len(other.parent), dtype=np.uint32)
        remap[0] = 0
        for cid in range(1, len(other.parent)):
            p = remap[other.parent[cid]]
            remap[cid] = self.child(int(p), other.kind[cid], other.names[other.name_id[cid]])
        return remap

    # -- linearization ----------------------------------------------------
    def preorder(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Canonical DFS-preorder linearization.

        Returns ``(pos, order, end)`` where ``pos[old_id] -> preorder index``,
        ``order[preorder index] -> old_id``, and ``end[preorder index]`` is
        one past the last preorder index of that node's subtree
        (``inclusive interval = [i, end[i])``).

        Children are visited in ``(kind, name)`` order rather than creation
        order: node ids in a concurrently-unified tree depend on scheduling,
        so sorting here makes the linearization — and therefore every
        database derived from it — a pure function of the tree's *content*.
        This is what lets the serial/threads/processes executors produce
        byte-identical PMS/CMS files.
        """
        n = len(self.parent)
        kids: list[list[int]] = [[] for _ in range(n)]
        for cid in range(1, n):
            kids[self.parent[cid]].append(cid)
        names, name_id, kind = self.names, self.name_id, self.kind
        for ch in kids:
            if len(ch) > 1:
                ch.sort(key=lambda c: (kind[c], names[name_id[c]]))
        pos = np.empty(n, dtype=np.int64)
        order = np.empty(n, dtype=np.int64)
        end = np.empty(n, dtype=np.int64)
        idx = 0
        # Iterative DFS with explicit post-visit records for `end`.
        stack: list[tuple[int, bool]] = [(0, False)]
        while stack:
            node, done = stack.pop()
            if done:
                end[pos[node]] = idx
                continue
            pos[node] = idx
            order[idx] = node
            idx += 1
            stack.append((node, True))
            for c in reversed(kids[node]):
                stack.append((c, False))
        return pos, order, end

    # -- serialization ----------------------------------------------------
    def to_arrays(self) -> dict[str, np.ndarray]:
        name_blob = "\x00".join(self.names).encode("utf-8")
        return {
            "parent": np.asarray(self.parent, dtype=np.int64),
            "kind": np.asarray(self.kind, dtype=np.uint8),
            "name_id": np.asarray(self.name_id, dtype=np.uint32),
            "names": np.frombuffer(name_blob, dtype=np.uint8),
        }

    @classmethod
    def from_arrays(cls, arrs: dict[str, np.ndarray]) -> "ContextTree":
        t = cls.__new__(cls)
        t.names = bytes(arrs["names"]).decode("utf-8").split("\x00")
        t._name_ids = {n: i for i, n in enumerate(t.names)}
        t.parent = [int(x) for x in arrs["parent"]]
        t.kind = [int(x) for x in arrs["kind"]]
        t.name_id = [int(x) for x in arrs["name_id"]]
        t._children = {
            (t.parent[c], t.kind[c], t.name_id[c]): c
            for c in range(1, len(t.parent))
        }
        return t
