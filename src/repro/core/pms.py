"""Profile-Major Sparse (PMS) analysis-results format (paper §3.2, §4.3.1).

One file holds the full (profile x context x metric) sparse tensor ordered
profile-major: a fixed-size *profile index* (offset/size per profile) plus a
sequence of per-profile CSR planes, each in the Fig.-1 measurement layout.

Because each plane's location is recorded in the index, planes may be
written **out of order** — the property the paper's double-buffered writer
relies on (§4.3.1).  Region allocation is a fetch-and-add on an atomic file
cursor (the paper's atomic / rank-0-server-thread protocol); writes use
``os.pwrite`` so concurrent writers never share a file position.

Layout::

    [0:4)   magic "RPMS"      [4:8)   version u32
    [8:16)  n_profiles u64    [16:24) meta_off u64 (patched at finalize)
    [24: 24+32*P)             index: per profile (offset, nbytes, n_ctx, n_vals) u64
    [... planes ...]          CSR planes, any order
    [meta_off: ...)           JSON meta + unified CCT arrays + summary stats
"""
from __future__ import annotations

import os
import struct
import threading

import numpy as np

from repro.utils import binio
from repro.core.cct import ContextTree
from repro.core.sparse import SparseMetrics

PMS_MAGIC = b"RPMS"
_HEADER = 24
_IDX_ENTRY = 32


class PMSWriter:
    def __init__(self, path, n_profiles: int):
        self.path = str(path)
        self.n_profiles = int(n_profiles)
        self._f = open(self.path, "w+b")
        self._fd = self._f.fileno()
        self._f.write(PMS_MAGIC + struct.pack("<I", 1))
        self._f.write(struct.pack("<QQ", self.n_profiles, 0))
        self._f.flush()  # all subsequent writes are positional pwrites
        self._index = np.zeros((self.n_profiles, 4), dtype=np.uint64)
        self._planes_start = _HEADER + _IDX_ENTRY * self.n_profiles
        self._pos = self._planes_start
        self._lock = threading.Lock()
        self._identities: list[dict | None] = [None] * self.n_profiles

    # -- the atomic region allocator (paper §4.3.1 / §4.4) ------------------
    def alloc(self, nbytes: int) -> int:
        with self._lock:
            off = self._pos
            self._pos += int(nbytes)
            return off

    def write_at(self, offset: int, data: bytes) -> None:
        os.pwrite(self._fd, data, offset)

    def record_plane(self, profile_id: int, offset: int, nbytes: int,
                     n_ctx: int, n_vals: int, identity: dict | None = None) -> None:
        self._index[profile_id] = (offset, nbytes, n_ctx, n_vals)
        if identity is not None:
            self._identities[profile_id] = identity

    def add_plane(self, profile_id: int, sm: SparseMetrics,
                  identity: dict | None = None) -> int:
        """Unbuffered path: encode, allocate, pwrite, record."""
        data = sm.encode()
        off = self.alloc(len(data))
        self.write_at(off, data)
        self.record_plane(profile_id, off, len(data), sm.n_contexts, sm.n_values, identity)
        return len(data)

    def finalize(self, tree: ContextTree | None = None, registry_json=None,
                 stats: dict[str, np.ndarray] | None = None, extra_meta=None) -> int:
        """Database 'completion' (paper §4.1): metadata + summary statistics."""
        meta_off = self._pos
        chunks = [binio.pack_json({
            "identities": self._identities,
            "registry": registry_json or [],
            "extra": extra_meta or {},
            "has_tree": tree is not None,
            "stats_fields": sorted(stats) if stats else [],
        })]
        if tree is not None:
            for a in tree.to_arrays().values():
                chunks.append(binio.pack_array(a))
        if stats:
            for k in sorted(stats):
                chunks.append(binio.pack_array(np.ascontiguousarray(stats[k])))
        blob = b"".join(chunks)
        self.write_at(meta_off, blob)
        self.write_at(_HEADER, self._index.tobytes())
        self.write_at(16, struct.pack("<Q", meta_off))
        end = meta_off + len(blob)
        self._f.truncate(end)
        self._f.close()
        return end

    def abort(self):
        self._f.close()


class PMSReader:
    def __init__(self, path):
        self.path = str(path)
        self._f = open(self.path, "rb")
        self._fd = self._f.fileno()
        head = os.pread(self._fd, _HEADER, 0)
        assert head[:4] == PMS_MAGIC, "not a PMS file"
        self.n_profiles, self.meta_off = struct.unpack_from("<QQ", head, 8)
        self.n_profiles = int(self.n_profiles)
        idx = os.pread(self._fd, _IDX_ENTRY * self.n_profiles, _HEADER)
        self.index = np.frombuffer(idx, dtype=np.uint64).reshape(self.n_profiles, 4)
        blob = os.pread(self._fd, os.fstat(self._fd).st_size - int(self.meta_off), int(self.meta_off))
        self.meta, off = binio.unpack_json(blob, 0)
        self.tree = None
        if self.meta.get("has_tree"):
            arrs = {}
            for key in ("parent", "kind", "name_id", "names"):
                arrs[key], off = binio.unpack_array(blob, off)
            self.tree = ContextTree.from_arrays(arrs)
        self.stats: dict[str, np.ndarray] = {}
        for k in self.meta.get("stats_fields", []):
            self.stats[k], off = binio.unpack_array(blob, off)

    def identity(self, pid: int) -> dict | None:
        return self.meta["identities"][pid]

    def plane_raw(self, pid: int) -> bytes:
        off, nbytes = int(self.index[pid, 0]), int(self.index[pid, 1])
        return os.pread(self._fd, nbytes, off)

    def plane(self, pid: int) -> SparseMetrics:
        if int(self.index[pid, 1]) == 0:
            return SparseMetrics.empty()
        sm, _ = SparseMetrics.decode(self.plane_raw(pid))
        return sm

    def query(self, pid: int, ctx: int, mid: int) -> float:
        return self.plane(pid).lookup(ctx, mid)

    def nbytes(self) -> int:
        return os.fstat(self._fd).st_size

    def close(self):
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
