"""HPCToolkit-style *dense* measurement & analysis baseline (paper §2, §5).

The paper evaluates against HPCToolkit's pre-existing workflow:

* measurement: each CCT node carries a **dense vector of metric values**
  (``n_ctx x n_metrics`` float64 per profile);
* analysis (hpcprof-mpi): profiles are merged into a **fully dense tensor**
  indexed by (profile, context, metric), one thread per MPI rank.

We reimplement that baseline honestly: it uses the same numpy primitives as
the streaming path (so the comparison isolates *dense-vs-sparse* and
*serial-vs-streaming-parallel*, not Python-vs-C++), writes its results as a
dense memory-mapped tensor, and computes the same inclusive metrics and
summary statistics.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.cct import ContextTree
from repro.core.metrics import INCLUSIVE_BIT
from repro.core.sparse import MeasurementProfile


# -- dense measurement format ------------------------------------------------

def dense_measurement_nbytes(n_ctx: int, n_metrics: int) -> int:
    """Per-profile dense CCT-vector size (the paper's 'Ratio' denominators)."""
    return n_ctx * n_metrics * 8


def write_dense_measurement(path, profile: MeasurementProfile, n_metrics: int) -> int:
    n_ctx = len(profile.tree.parent)
    mat = profile.metrics.to_dense(n_ctx, n_metrics)
    with open(path, "wb") as f:
        f.write(json.dumps({"n_ctx": n_ctx, "n_metrics": n_metrics}).encode() + b"\n")
        f.write(mat.tobytes())
    return os.path.getsize(path)


# -- dense analysis (hpcprof-analog) ------------------------------------------

class DenseAnalysis:
    """Serial dense merge -> propagate -> stats -> dense on-disk tensor."""

    def __init__(self, out_path):
        self.out_path = str(out_path)

    def run(self, profile_paths: list[str]) -> dict:
        # Phase 1 (serial): unify trees.
        profiles = [MeasurementProfile.load(p) for p in profile_paths]
        unified = ContextTree()
        remaps = [unified.merge(p.tree) for p in profiles]
        n_ctx = len(unified.parent)
        n_metrics_in = max(
            (int(p.metrics.mid.max()) + 1 for p in profiles if p.metrics.n_values), default=0
        )
        # dense result tensor: (P, C, 2*M) — exclusive + inclusive planes
        n_out = 2 * max(n_metrics_in, 1)
        parent = unified.parent_array()
        P = len(profiles)
        tensor = np.lib.format.open_memmap(
            self.out_path, mode="w+", dtype=np.float64, shape=(P, n_ctx, n_out)
        )
        # Phase 2 (serial over profiles): dense propagation + write.
        pos, order, end = unified.preorder()
        for i, (p, remap) in enumerate(zip(profiles, remaps)):
            sm = p.metrics.remap_contexts(remap)
            dense = sm.to_dense(n_ctx, n_metrics_in) if n_metrics_in else np.zeros((n_ctx, 1))
            pre = dense[order]  # preorder layout
            ps = np.zeros((n_ctx + 1, pre.shape[1]))
            np.cumsum(pre, axis=0, out=ps[1:])
            # inclusive value of preorder slot i is ps[end[i]] - ps[i];
            # scatter back from preorder slots to context ids via `order`
            incl_ctx = np.empty_like(pre)
            incl_ctx[order] = ps[end] - ps[np.arange(n_ctx)]
            tensor[i, :, :n_metrics_in] = dense
            tensor[i, :, max(n_metrics_in, 1):max(n_metrics_in, 1) + dense.shape[1]] = incl_ctx
        tensor.flush()
        # Phase 3: dense summary statistics over the full tensor.
        nz = tensor != 0.0
        cnt = nz.sum(axis=0)
        tot = tensor.sum(axis=0)
        stats = {"count": cnt, "sum": tot}
        result_bytes = os.path.getsize(self.out_path)
        return {
            "n_ctx": n_ctx,
            "n_profiles": P,
            "n_metrics_out": n_out,
            "result_bytes": result_bytes,
            "stats": stats,
            "tree": unified,
        }

    def query(self, pid: int, ctx: int, mid: int, *, inclusive: bool = False) -> float:
        tensor = np.load(self.out_path, mmap_mode="r")
        m_half = tensor.shape[2] // 2
        col = (mid & ~INCLUSIVE_BIT) + (m_half if (inclusive or mid & INCLUSIVE_BIT) else 0)
        return float(tensor[pid, ctx, col])
