"""Integrated trace file (paper §4, footnote 2).

"The integrated trace file format is simple: a segment for each trace and a
table of contents that points to the start and end of each trace.  The
starting location of each trace is computed with a prefix sum over trace
lengths.  Traces can be written in parallel."
"""
from __future__ import annotations

import os
import struct
import threading

import numpy as np

from repro.core.sparse import Trace

TRC_MAGIC = b"RTRC"
_HEADER = 16


def segment_nbytes(n_samples: int) -> int:
    return 12 * n_samples  # f64 time + u32 ctx per sample


class TraceDBWriter:
    """Offsets from a prefix sum over (known) trace lengths; parallel pwrites."""

    def __init__(self, path, lengths: list[int]):
        self.path = str(path)
        n = len(lengths)
        sizes = np.array([segment_nbytes(l) for l in lengths], dtype=np.uint64)
        self.offsets = np.zeros(n + 1, dtype=np.uint64)
        np.cumsum(sizes, out=self.offsets[1:])
        self.lengths = np.asarray(lengths, dtype=np.uint64)
        data_start = _HEADER + 16 * n + 8
        self.offsets += np.uint64(data_start)
        self._f = open(self.path, "w+b")
        self._fd = self._f.fileno()
        self._f.write(TRC_MAGIC + struct.pack("<I", 1) + struct.pack("<Q", n))
        toc = np.empty((n, 2), dtype=np.uint64)
        toc[:, 0] = self.offsets[:-1]
        toc[:, 1] = self.lengths
        self._f.write(toc.tobytes())
        self._f.write(struct.pack("<Q", int(self.offsets[-1])))
        self._f.flush()  # subsequent trace writes are positional pwrites
        self._lock = threading.Lock()

    def write_trace(self, idx: int, trace: Trace) -> None:
        assert trace.time.size == int(self.lengths[idx])
        buf = trace.time.astype("<f8").tobytes() + trace.ctx.astype("<u4").tobytes()
        os.pwrite(self._fd, buf, int(self.offsets[idx]))

    def close(self):
        self._f.truncate(int(self.offsets[-1]))
        self._f.close()


class TraceDBReader:
    def __init__(self, path):
        self._f = open(str(path), "rb")
        self._fd = self._f.fileno()
        head = os.pread(self._fd, _HEADER, 0)
        assert head[:4] == TRC_MAGIC
        (self.n,) = struct.unpack_from("<Q", head, 8)
        self.n = int(self.n)
        toc = os.pread(self._fd, 16 * self.n, _HEADER)
        self.toc = np.frombuffer(toc, dtype=np.uint64).reshape(self.n, 2)

    def trace(self, idx: int) -> Trace:
        off, ln = int(self.toc[idx, 0]), int(self.toc[idx, 1])
        buf = os.pread(self._fd, segment_nbytes(ln), off)
        t = np.frombuffer(buf[: 8 * ln], dtype="<f8")
        c = np.frombuffer(buf[8 * ln :], dtype="<u4")
        return Trace(t, c)

    def close(self):
        self._f.close()
