"""Metric propagation & placeholder redistribution (paper §4.1.2-4.1.3).

The measurement subsystem records only *exclusive* costs.  Analysis derives
*inclusive* costs by propagating exclusive values to every ancestor.

TPU-shaped formulation (DESIGN.md §4): with the unified CCT linearized in
DFS preorder, a node's subtree is the contiguous interval ``[i, end[i])``,
so for a dense preorder value vector ``v``::

    inclusive[i] = cumsum(v)[end[i]] - cumsum(v)[i]   (exclusive-prefix cumsum)

One streaming pass instead of a recursive walk; batched over the (few)
metrics a profile actually observed.  The Pallas ``blockscan`` kernel is the
TPU implementation of the cumsum; this module is the numpy engine used by
the post-mortem analysis tool.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import INCLUSIVE_BIT
from repro.core.sparse import SparseMetrics


def propagate_inclusive(
    metrics: SparseMetrics,
    pos: np.ndarray,
    end: np.ndarray,
    *,
    keep_exclusive: bool = True,
) -> SparseMetrics:
    """Exclusive -> exclusive+inclusive for one profile.

    ``pos``/``end`` come from ``ContextTree.preorder()`` of the *unified*
    tree; ``metrics`` must already be remapped onto unified context ids.
    Inclusive values are emitted under ``mid | INCLUSIVE_BIT`` for every
    context with a non-zero subtree sum.
    """
    n = pos.size
    rows, mids, vals = metrics.triplets()
    if rows.size == 0:
        return metrics
    prof_mids = np.unique(mids)
    m = prof_mids.size
    col_of = np.zeros(int(prof_mids.max()) + 1, dtype=np.int64)
    col_of[prof_mids] = np.arange(m)

    dense = np.zeros((n, m), dtype=np.float64)
    dense[pos[rows], col_of[mids]] = vals
    # exclusive-prefix cumsum: ps[i] = sum(dense[:i])
    ps = np.zeros((n + 1, m), dtype=np.float64)
    np.cumsum(dense, axis=0, out=ps[1:])
    order_idx = np.arange(n)
    incl = ps[end] - ps[order_idx]  # (n, m) inclusive values per preorder slot

    ir, ic = np.nonzero(incl)
    # map preorder slot back to context id: pos is a permutation; invert it
    inv = np.empty(n, dtype=np.int64)
    inv[pos] = np.arange(n)
    out_rows = [inv[ir]]
    out_mids = [prof_mids[ic] | INCLUSIVE_BIT]
    out_vals = [incl[ir, ic]]
    if keep_exclusive:
        out_rows.append(rows)
        out_mids.append(mids)
        out_vals.append(vals)
    return SparseMetrics.from_triplets(
        np.concatenate(out_rows), np.concatenate(out_mids), np.concatenate(out_vals)
    )


def propagate_inclusive_reference(
    metrics: SparseMetrics, parent: np.ndarray, *, keep_exclusive: bool = True
) -> SparseMetrics:
    """Naive per-node walk (the paper's recursive formulation) — test oracle."""
    rows, mids, vals = metrics.triplets()
    out: dict[tuple[int, int], float] = {}
    for r, m, v in zip(rows, mids, vals):
        node = int(r)
        while node != -1:
            key = (node, int(m) | INCLUSIVE_BIT)
            out[key] = out.get(key, 0.0) + float(v)
            node = int(parent[node])
        if keep_exclusive:
            key = (int(r), int(m))
            out[key] = out.get(key, 0.0) + float(v)
    if not out:
        return metrics
    ks = np.array([k for k in out], dtype=np.int64)
    vs = np.array([out[tuple(k)] for k in ks], dtype=np.float64)
    return SparseMetrics.from_triplets(ks[:, 0], ks[:, 1], vs)


def redistribute_placeholders(
    metrics: SparseMetrics,
    routes: dict[int, tuple[np.ndarray, np.ndarray]],
) -> SparseMetrics:
    """GPU-context-reconstruction redistribution (paper §4.1.3).

    ``routes`` maps a placeholder context id ("in superposition") to
    ``(leaf_ctx_ids, weights)``; the placeholder's costs are split across the
    reconstructed leaf contexts proportionally to observed/approximated call
    counts, before inclusive propagation so the split costs flow up their
    full reconstructed call paths.
    """
    if not routes:
        return metrics
    rows, mids, vals = metrics.triplets()
    is_ph = np.isin(rows, np.fromiter(routes.keys(), dtype=np.int64))
    leaf_ctx, e_lens, norm_w = expand_routes(rows[is_ph], routes)
    return SparseMetrics.from_triplets(
        np.concatenate([rows[~is_ph], leaf_ctx]),
        np.concatenate([mids[~is_ph], np.repeat(mids[is_ph], e_lens)]),
        np.concatenate([vals[~is_ph], np.repeat(vals[is_ph], e_lens) * norm_w]),
    )


def expand_routes(
    ph_rows: np.ndarray, routes: dict[int, tuple[np.ndarray, np.ndarray]]
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized route-expansion core, shared with the fused pipeline.

    For placeholder context ids ``ph_rows`` (stream order, duplicates
    allowed), returns ``(leaf_ctx, lens, norm_w)``: the flattened route
    targets per entry, each entry's target count, and the per-route
    normalized weights gathered per target — one ``np.repeat``/
    ``np.concatenate`` pass instead of a Python loop per placeholder row.
    Per-element arithmetic matches the historical loop — the caller applies
    ``value * norm_w`` where ``norm_w = w / w.sum()`` — and expansion order
    is (entry order, then route order), so downstream summation order is
    unchanged.
    """
    ph_ids = np.fromiter(routes.keys(), dtype=np.int64)
    targets = [np.asarray(routes[int(c)][0], dtype=np.int64) for c in ph_ids]
    weights = [np.asarray(routes[int(c)][1], dtype=np.float64) for c in ph_ids]
    weights = [w / w.sum() for w in weights]
    lens = np.array([t.size for t in targets], dtype=np.int64)
    flat_tgt = np.concatenate(targets) if targets else np.empty(0, np.int64)
    flat_w = np.concatenate(weights) if weights else np.empty(0, np.float64)
    route_off = np.concatenate([[0], np.cumsum(lens)])

    order = np.argsort(ph_ids, kind="stable")
    ridx = order[np.searchsorted(ph_ids[order], ph_rows)]
    e_lens = lens[ridx]
    total = int(e_lens.sum())
    starts = np.repeat(route_off[ridx], e_lens)
    local = np.arange(total) - np.repeat(
        np.concatenate([[0], np.cumsum(e_lens)])[:-1], e_lens)
    gather = starts + local
    return flat_tgt[gather], e_lens, flat_w[gather]
