"""Metric propagation & placeholder redistribution (paper §4.1.2-4.1.3).

The measurement subsystem records only *exclusive* costs.  Analysis derives
*inclusive* costs by propagating exclusive values to every ancestor.

TPU-shaped formulation (DESIGN.md §4): with the unified CCT linearized in
DFS preorder, a node's subtree is the contiguous interval ``[i, end[i])``,
so for a dense preorder value vector ``v``::

    inclusive[i] = cumsum(v)[end[i]] - cumsum(v)[i]   (exclusive-prefix cumsum)

One streaming pass instead of a recursive walk; batched over the (few)
metrics a profile actually observed.  The Pallas ``blockscan`` kernel is the
TPU implementation of the cumsum; this module is the numpy engine used by
the post-mortem analysis tool.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import INCLUSIVE_BIT
from repro.core.sparse import SparseMetrics


def propagate_inclusive(
    metrics: SparseMetrics,
    pos: np.ndarray,
    end: np.ndarray,
    *,
    keep_exclusive: bool = True,
) -> SparseMetrics:
    """Exclusive -> exclusive+inclusive for one profile.

    ``pos``/``end`` come from ``ContextTree.preorder()`` of the *unified*
    tree; ``metrics`` must already be remapped onto unified context ids.
    Inclusive values are emitted under ``mid | INCLUSIVE_BIT`` for every
    context with a non-zero subtree sum.
    """
    n = pos.size
    rows, mids, vals = metrics.triplets()
    if rows.size == 0:
        return metrics
    prof_mids = np.unique(mids)
    m = prof_mids.size
    col_of = np.zeros(int(prof_mids.max()) + 1, dtype=np.int64)
    col_of[prof_mids] = np.arange(m)

    dense = np.zeros((n, m), dtype=np.float64)
    dense[pos[rows], col_of[mids]] = vals
    # exclusive-prefix cumsum: ps[i] = sum(dense[:i])
    ps = np.zeros((n + 1, m), dtype=np.float64)
    np.cumsum(dense, axis=0, out=ps[1:])
    order_idx = np.arange(n)
    incl = ps[end] - ps[order_idx]  # (n, m) inclusive values per preorder slot

    ir, ic = np.nonzero(incl)
    # map preorder slot back to context id: pos is a permutation; invert it
    inv = np.empty(n, dtype=np.int64)
    inv[pos] = np.arange(n)
    out_rows = [inv[ir]]
    out_mids = [prof_mids[ic] | INCLUSIVE_BIT]
    out_vals = [incl[ir, ic]]
    if keep_exclusive:
        out_rows.append(rows)
        out_mids.append(mids)
        out_vals.append(vals)
    return SparseMetrics.from_triplets(
        np.concatenate(out_rows), np.concatenate(out_mids), np.concatenate(out_vals)
    )


def propagate_inclusive_reference(
    metrics: SparseMetrics, parent: np.ndarray, *, keep_exclusive: bool = True
) -> SparseMetrics:
    """Naive per-node walk (the paper's recursive formulation) — test oracle."""
    rows, mids, vals = metrics.triplets()
    out: dict[tuple[int, int], float] = {}
    for r, m, v in zip(rows, mids, vals):
        node = int(r)
        while node != -1:
            key = (node, int(m) | INCLUSIVE_BIT)
            out[key] = out.get(key, 0.0) + float(v)
            node = int(parent[node])
        if keep_exclusive:
            key = (int(r), int(m))
            out[key] = out.get(key, 0.0) + float(v)
    if not out:
        return metrics
    ks = np.array([k for k in out], dtype=np.int64)
    vs = np.array([out[tuple(k)] for k in ks], dtype=np.float64)
    return SparseMetrics.from_triplets(ks[:, 0], ks[:, 1], vs)


def redistribute_placeholders(
    metrics: SparseMetrics,
    routes: dict[int, tuple[np.ndarray, np.ndarray]],
) -> SparseMetrics:
    """GPU-context-reconstruction redistribution (paper §4.1.3).

    ``routes`` maps a placeholder context id ("in superposition") to
    ``(leaf_ctx_ids, weights)``; the placeholder's costs are split across the
    reconstructed leaf contexts proportionally to observed/approximated call
    counts, before inclusive propagation so the split costs flow up their
    full reconstructed call paths.
    """
    if not routes:
        return metrics
    rows, mids, vals = metrics.triplets()
    is_ph = np.isin(rows, np.fromiter(routes.keys(), dtype=np.int64))
    keep_r, keep_m, keep_v = rows[~is_ph], mids[~is_ph], vals[~is_ph]
    new_r, new_m, new_v = [keep_r], [keep_m], [keep_v]
    for r, m, v in zip(rows[is_ph], mids[is_ph], vals[is_ph]):
        targets, w = routes[int(r)]
        w = np.asarray(w, dtype=np.float64)
        w = w / w.sum()
        new_r.append(np.asarray(targets, dtype=np.int64))
        new_m.append(np.full(len(targets), m, dtype=np.int64))
        new_v.append(v * w)
    return SparseMetrics.from_triplets(
        np.concatenate(new_r), np.concatenate(new_m), np.concatenate(new_v)
    )
