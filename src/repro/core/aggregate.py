"""The streaming aggregation engine (paper §4).

Dataflow (Fig. 3 of the paper): profile *sources* are streamed in parallel
by a pool of workers; contexts are unified and lexically expanded
("edit" + U), metric values are redistributed across reconstructed routes,
propagated to inclusive costs, accumulated into cross-profile statistics
(+), and written *as soon as they are computed* to the PMS database through
a two-buffer out-of-order writer; traces are remapped and written in
parallel at offsets precomputed by a prefix sum.  A final "completion"
writes metadata + summary statistics and generates the CMS file.

Two phases, exactly as §4.4:

* **phase 1** — parse context/identity sections, unify CCTs (the reduction
  payload in multi-rank mode);
* **phase 2** — parse metrics/traces, remap onto final context ids,
  propagate, accumulate, write.

Execution substrate — the :mod:`repro.runtime` backends (paper §4.2 / §4.4):

* ``serial`` / ``threads`` run both phases in-process; phase-1 uniquing
  serializes through one lock (GIL realities, see DESIGN.md §4) while
  everything downstream runs without shared mutable state;
* ``processes`` shards profiles across multiprocessing workers: each worker
  unifies a *local* CCT over its shard (no uniquing lock at all) and the
  shard trees merge up a reduction tree (§4.4 phase 1); phase-2 propagate/
  encode runs in workers, which ship encoded planes back to the parent — a
  single writer feeding :class:`TwoBufferWriter`.

**Determinism contract:** all three backends produce byte-identical PMS and
CMS databases for the same inputs and config.  Three mechanisms pin this
down: (1) ``ContextTree.preorder`` orders children canonically so final
context ids are a function of tree *content*, not insertion schedule;
(2) plane appends pass through :class:`repro.runtime.OrderedSink`, pinning
region allocation to profile order; (3) summary statistics are accumulated
per profile and folded in profile order by a streaming carry-chain reducer
whose merge shape is a pure function of the profile count, pinning the
floating-point op order with only O(log n) accumulators resident.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import cms as cms_mod
from repro.core.cct import ContextTree
from repro.core.lexical import StructureInfo, expand_profile_tree
from repro.core.pms import PMSWriter
from repro.core.propagate import propagate_inclusive, redistribute_placeholders
from repro.core.sparse import MeasurementProfile, Trace
from repro.core.stats import StatsAccumulator
from repro.core.traces import TraceDBWriter
from repro.runtime import OrderedSink, get_executor
from repro.runtime.reduce import (StreamingReducer, TreeWithMaps,
                                  merge_tree_with_maps, tree_reduce)


@dataclass
class AggregationConfig:
    n_threads: int = 4                   # legacy knob; used when n_workers unset
    executor: str = "threads"            # serial | threads | processes | ranks
    n_workers: int | None = None         # worker count / rank count per backend
    buffer_bytes: int = 1 << 20          # PMS double-buffer flush threshold
    sink_window: int | None = None       # ordered-sink out-of-order bound for
                                         # in-process backends; None = auto
                                         # (2 x workers), 0 = unbounded
    cms_workers: int = 4
    cms_strategy: str = "vectorized"     # or "heap" (paper-faithful merge)
    cms_balance: str = "dynamic"         # GLB (paper §4.4) or "static"
    group_target_bytes: int = 1 << 20
    write_cms: bool = True
    write_traces: bool = True
    keep_exclusive: bool = True

    @property
    def workers(self) -> int:
        return max(1, self.n_threads if self.n_workers is None else self.n_workers)

    @property
    def effective_sink_window(self) -> int | None:
        """Out-of-order plane budget for the in-process ordered sink.

        ``None`` (unbounded) only when explicitly requested with 0; the
        default bounds residency at 2x the worker count — enough slack that
        workers rarely stall, small enough that a slow profile 0 cannot
        force O(n_profiles) encoded planes to buffer (ROADMAP known limit).
        """
        if self.sink_window is None:
            return max(2 * self.workers, 2)
        return self.sink_window if self.sink_window > 0 else None


@dataclass
class AnalysisResult:
    pms_path: str
    cms_path: str | None
    trace_path: str | None
    n_profiles: int
    n_contexts: int
    n_values: int
    timings: dict[str, float] = field(default_factory=dict)
    sizes: dict[str, int] = field(default_factory=dict)


class _PhaseTimer:
    """Accumulates io/compute seconds across threads (Fig. 6 breakdown)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.acc: dict[str, float] = {}

    def add(self, key: str, dt: float) -> None:
        with self._lock:
            self.acc[key] = self.acc.get(key, 0.0) + dt


class TwoBufferWriter:
    """The two-buffer PMS output scheme of paper §4.3.1.

    Threads append encoded planes to the active buffer; whoever crosses the
    threshold swaps buffers (fetch-and-add allocates the file region) and
    performs the write while other threads keep appending to the twin.
    """

    def __init__(self, pms: PMSWriter, threshold: int, timer: _PhaseTimer):
        self._pms = pms
        self._threshold = threshold
        self._timer = timer
        self._pool: queue.Queue = queue.Queue()
        self._pool.put(bytearray())
        self._pool.put(bytearray())
        self._buf: bytearray = self._pool.get()
        self._recs: list[tuple[int, int, int, int, int, dict | None]] = []
        self._lock = threading.Lock()

    def append(self, pid: int, payload: bytes, n_ctx: int, n_vals: int,
               identity: dict | None = None) -> None:
        to_write = None
        with self._lock:
            off = len(self._buf)
            self._buf += payload
            self._recs.append((pid, off, len(payload), n_ctx, n_vals, identity))
            if len(self._buf) >= self._threshold:
                to_write = (self._buf, self._recs)
                # blocks only if both buffers are mid-write (backpressure)
                self._buf = self._pool.get()
                self._recs = []
        if to_write is not None:
            self._flush(*to_write)

    def _flush(self, buf: bytearray, recs) -> None:
        if not buf:
            self._recycle(buf)
            return
        region = self._pms.alloc(len(buf))
        t0 = time.perf_counter()
        self._pms.write_at(region, bytes(buf))
        self._timer.add("io_write", time.perf_counter() - t0)
        for pid, off, nb, n_ctx, n_vals, ident in recs:
            self._pms.record_plane(pid, region + off, nb, n_ctx, n_vals, ident)
        self._recycle(buf)

    def _recycle(self, buf: bytearray) -> None:
        buf.clear()
        self._pool.put(buf)

    def close(self) -> None:
        with self._lock:
            to_write = (self._buf, self._recs)
            self._buf = self._pool.get()
            self._recs = []
        self._flush(*to_write)


def _load_structures(prof: MeasurementProfile,
                     cache: dict[str, StructureInfo]) -> dict[str, StructureInfo]:
    """Eagerly acquire lexical info for the profile's binaries (paper §4.2.3)
    and return the subset visible to this profile: exactly the structure
    files named in its file-paths section.  Restricting visibility per
    profile (instead of handing every profile the whole shared cache) keeps
    the expansion a pure function of the profile — required for
    cross-executor determinism, so every phase-1 path must go through this
    one helper."""
    for sp in prof.file_paths:
        if sp.endswith(".struct.json") and os.path.exists(sp) \
                and sp not in cache:
            cache[sp] = StructureInfo.load(sp)
    return {sp: cache[sp] for sp in prof.file_paths if sp in cache}


def _merge_stats(a: StatsAccumulator, b: StatsAccumulator) -> StatsAccumulator:
    a.merge(b)
    return a


class StreamingAggregator:
    """Single-rank engine; :mod:`repro.core.reduction` composes ranks."""

    def __init__(self, out_dir, config: AggregationConfig | None = None):
        self.out_dir = str(out_dir)
        os.makedirs(self.out_dir, exist_ok=True)
        self.cfg = config or AggregationConfig()

    def _executor(self):
        return get_executor(self.cfg.executor, self.cfg.workers)

    # -- phase 1: contexts ---------------------------------------------------
    def parse_contexts(self, profile_paths: list[str], timer: _PhaseTimer,
                       unified: ContextTree | None = None, executor=None):
        """Parallel parse + unify; returns (unified, remaps, routes, meta).

        In-process only (the body closes over the shared tree); the
        ``processes`` backend goes through :func:`_phase1_shard_worker`.
        """
        cfg = self.cfg
        ex = executor or get_executor(cfg.executor, cfg.workers)
        if not ex.in_process:
            raise ValueError(
                f"parse_contexts requires an in-process executor, got "
                f"{ex.name!r}; use StreamingAggregator.run for the sharded "
                f"path, or pass executor= explicitly")
        unified = unified or ContextTree()
        structures: dict[str, StructureInfo] = {}
        struct_lock = threading.Lock()
        uniq_lock = threading.Lock()
        n = len(profile_paths)
        remaps: list[np.ndarray | None] = [None] * n
        routes: list[dict] = [{}] * n
        identities: list[dict] = [{}] * n
        trace_lens = np.zeros(n, dtype=np.int64)
        registry_jsons: list[list] = [[]] * n

        def body(i: int):
            t0 = time.perf_counter()
            prof = MeasurementProfile.load(profile_paths[i])
            timer.add("io_read", time.perf_counter() - t0)
            t1 = time.perf_counter()
            with struct_lock:
                own = _load_structures(prof, structures)
            with uniq_lock:  # uniquing (U) — see module docstring on locking
                remap, rts = expand_profile_tree(unified, prof.tree, own)
            remaps[i] = remap
            routes[i] = rts
            identities[i] = prof.identity
            trace_lens[i] = prof.trace.time.size
            registry_jsons[i] = prof.environment.get("registry", [])
            timer.add("compute", time.perf_counter() - t1)

        ex.parallel_for(n, body)
        return unified, remaps, routes, identities, trace_lens, registry_jsons

    # -- full run --------------------------------------------------------------
    def run(self, profile_paths: list[str]) -> AnalysisResult:
        with self._executor() as ex:
            if ex.driver == "ranks":
                # whole-run driver backend (paper §4.4): n_workers ranks,
                # n_threads threads per rank; imported lazily — the rank
                # driver composes *this* engine, so the import must not be
                # circular at module load
                from repro.core.reduction import aggregate_multiprocess
                return aggregate_multiprocess(
                    profile_paths, self.out_dir, n_ranks=ex.n_workers,
                    threads_per_rank=self.cfg.n_threads, config=self.cfg)
            if ex.in_process:
                return self._run_inprocess(profile_paths, ex)
            return self._run_sharded(profile_paths, ex)

    # -- in-process path (serial / threads) ------------------------------------
    def _run_inprocess(self, profile_paths: list[str], ex) -> AnalysisResult:
        cfg = self.cfg
        timer = _PhaseTimer()
        t_start = time.perf_counter()
        n = len(profile_paths)

        # ---- phase 1
        t0 = time.perf_counter()
        unified, remaps, routes, identities, trace_lens, registries = (
            self.parse_contexts(profile_paths, timer, executor=ex))
        # renumber contexts to canonical preorder ids: subtree intervals
        # become contiguous and CMS context order matches tree order
        pos, order, end = unified.preorder()
        final_tree = _renumber(unified, pos, order)
        n_ctx = len(final_tree)
        timer.add("phase1", time.perf_counter() - t0)

        # ---- phase 2
        t0 = time.perf_counter()
        pms_path = os.path.join(self.out_dir, "db.pms")
        pms = PMSWriter(pms_path, n)
        writer = TwoBufferWriter(pms, cfg.buffer_bytes, timer)
        # stats fold inside the ordered sink: in profile order with a shape
        # that is a pure function of n, and only O(log n) accumulators live
        stats_reducer = StreamingReducer(_merge_stats)

        def consume(i: int, item):
            payload, p_ctx, p_vals, identity, acc = item
            writer.append(i, payload, p_ctx, p_vals, identity)
            stats_reducer.push(acc)

        # bounded out-of-order buffer: producers for far-ahead profiles block
        # instead of stacking encoded planes (safe in-process: the worker
        # holding the next index is never blocked, and failures poison the
        # sink so blocked peers wake — see body's except clause)
        sink = OrderedSink(consume, window=cfg.effective_sink_window)
        trace_path = None
        trace_writer = None
        if cfg.write_traces and trace_lens.sum() > 0:
            trace_path = os.path.join(self.out_dir, "db.trc")
            trace_writer = TraceDBWriter(trace_path, [int(x) for x in trace_lens])
        nvals = np.zeros(n, dtype=np.int64)
        end_arr = end  # by preorder id
        ident_pos = np.arange(n_ctx)

        def body(i: int):
            try:
                t0 = time.perf_counter()
                prof = MeasurementProfile.load(profile_paths[i])
                timer.add("io_read", time.perf_counter() - t0)
                t1 = time.perf_counter()
                remap_final = pos[np.asarray(remaps[i], dtype=np.int64)]
                sm = prof.metrics.remap_contexts(remap_final)
                if routes[i]:
                    rts = {int(pos[ph]): (pos[t_], w) for ph, (t_, w) in routes[i].items()}
                    sm = redistribute_placeholders(sm, rts)
                sm = propagate_inclusive(sm, ident_pos, end_arr,
                                         keep_exclusive=cfg.keep_exclusive)
                acc = StatsAccumulator()
                acc.update(sm)
                nvals[i] = sm.n_values
                payload = sm.encode()
                timer.add("compute", time.perf_counter() - t1)
                # in-order append: pins region allocation to profile order
                sink.put(i, (payload, sm.n_contexts, sm.n_values, identities[i], acc))
                if trace_writer is not None and prof.trace.time.size:
                    tr = prof.trace.remap_contexts(remap_final)
                    t2 = time.perf_counter()
                    trace_writer.write_trace(i, tr)
                    timer.add("io_write", time.perf_counter() - t2)
            except BaseException as e:
                sink.fail(e)  # wake producers blocked on the bounded window
                raise

        try:
            ex.parallel_for(n, body)
            sink.close()
            writer.close()
        except BaseException:
            pms.abort()
            if trace_writer is not None:
                trace_writer.close()
            raise
        if trace_writer is not None:
            trace_writer.close()
        timer.add("phase2", time.perf_counter() - t0)

        return self._complete(pms, final_tree, stats_reducer.result(),
                              registries, trace_path, timer, t_start, n,
                              n_ctx, int(nvals.sum()))

    # -- sharded path (processes) ----------------------------------------------
    def _run_sharded(self, profile_paths: list[str], ex) -> AnalysisResult:
        cfg = self.cfg
        timer = _PhaseTimer()
        t_start = time.perf_counter()
        n = len(profile_paths)
        shards = ex.shards(n)

        # ---- phase 1: per-shard local CCTs, merged by a reduction tree ----
        t0 = time.perf_counter()
        shard_paths = [[profile_paths[i] for i in sh] for sh in shards]
        results1: dict[int, dict] = dict(
            ex.map_unordered(_phase1_shard_worker, shard_paths))
        items = [
            TreeWithMaps(ContextTree.from_arrays(results1[k]["tree"]),
                         {k: np.arange(len(results1[k]["tree"]["parent"]))})
            for k in range(len(shards))
        ]
        if items:
            merged, _ = tree_reduce(items, merge_tree_with_maps, 2)
        else:
            merged = TreeWithMaps(ContextTree(), {})
        pos, order, end = merged.tree.preorder()
        final_tree = _renumber(merged.tree, pos, order)
        n_ctx = len(final_tree)

        # broadcast final ids back: compose per-profile remaps and routes
        remaps_final: list[np.ndarray | None] = [None] * n
        routes_final: list[dict] = [{}] * n
        identities: list[dict | None] = [None] * n
        registries: list[list] = [[]] * n
        trace_lens = np.zeros(n, dtype=np.int64)
        for k, sh in enumerate(shards):
            res = results1[k]
            shard_map = pos[merged.maps[k]]  # local ctx -> final preorder id
            for j, g in enumerate(sh):
                remaps_final[g] = shard_map[np.asarray(res["remaps"][j], np.int64)]
                routes_final[g] = {
                    int(shard_map[ph]): (shard_map[np.asarray(t_, np.int64)], w)
                    for ph, (t_, w) in res["routes"][j].items()
                }
                identities[g] = res["identities"][j]
                registries[g] = res["registries"][j]
                trace_lens[g] = res["trace_lens"][j]
        timer.add("phase1", time.perf_counter() - t0)

        # ---- phase 2: propagate/encode in workers, single writer here ----
        t0 = time.perf_counter()
        pms_path = os.path.join(self.out_dir, "db.pms")
        pms = PMSWriter(pms_path, n)
        writer = TwoBufferWriter(pms, cfg.buffer_bytes, timer)
        trace_path = None
        trace_writer = None
        if cfg.write_traces and trace_lens.sum() > 0:
            trace_path = os.path.join(self.out_dir, "db.trc")
            trace_writer = TraceDBWriter(trace_path, [int(x) for x in trace_lens])
        stats_reducer = StreamingReducer(_merge_stats)
        nvals = np.zeros(n, dtype=np.int64)

        def consume(i: int, item):
            payload, p_ctx, p_vals, stat_arrays, ttime, tctx = item
            writer.append(i, payload, p_ctx, p_vals, identities[i])
            stats_reducer.push(StatsAccumulator.from_arrays(stat_arrays))
            nvals[i] = p_vals
            if trace_writer is not None and ttime.size:
                t2 = time.perf_counter()
                trace_writer.write_trace(i, Trace(ttime, tctx))
                timer.add("io_write", time.perf_counter() - t2)

        sink = OrderedSink(consume)
        tasks = [(profile_paths[i], remaps_final[i], routes_final[i])
                 for i in range(n)]
        try:
            for i, result in ex.map_unordered(
                    _phase2_profile_worker, tasks,
                    initializer=_phase2_init,
                    initargs=(end, cfg.keep_exclusive, cfg.write_traces)):
                sink.put(i, result)
            sink.close()
            writer.close()
        except BaseException:
            pms.abort()
            if trace_writer is not None:
                trace_writer.close()
            raise
        if trace_writer is not None:
            trace_writer.close()
        timer.add("phase2", time.perf_counter() - t0)

        return self._complete(pms, final_tree, stats_reducer.result(),
                              registries, trace_path, timer, t_start, n,
                              n_ctx, int(nvals.sum()))

    # -- completion (paper: overlapped with CMS generation) --------------------
    def _complete(self, pms, final_tree, root_acc, registries,
                  trace_path, timer, t_start, n, n_ctx, n_values) -> AnalysisResult:
        cfg = self.cfg
        t0 = time.perf_counter()
        if root_acc is None:
            root_acc = StatsAccumulator()
        stats = root_acc.finalize()
        registry_json = next((r for r in registries if r), [])
        pms_bytes = pms.finalize(tree=final_tree, registry_json=registry_json,
                                 stats={k: np.asarray(v, np.float64)
                                        for k, v in stats.items()})
        cms_path = None
        cms_bytes = 0
        if cfg.write_cms:
            cms_path = os.path.join(self.out_dir, "db.cms")
            t2 = time.perf_counter()
            cms_bytes = cms_mod.build_cms(
                pms.path, cms_path, n_workers=cfg.cms_workers,
                strategy=cfg.cms_strategy, balance=cfg.cms_balance,
                group_target_bytes=cfg.group_target_bytes,
                executor=cfg.executor)
            timer.add("cms", time.perf_counter() - t2)
        timer.add("completion", time.perf_counter() - t0)
        timer.add("total", time.perf_counter() - t_start)

        sizes = {"pms": pms_bytes, "cms": cms_bytes}
        if trace_path:
            sizes["traces"] = os.path.getsize(trace_path)
        return AnalysisResult(
            pms_path=pms.path, cms_path=cms_path, trace_path=trace_path,
            n_profiles=n, n_contexts=n_ctx, n_values=n_values,
            timings=dict(timer.acc), sizes=sizes,
        )


# ---------------------------------------------------------------------------
# process-backend worker bodies (module-level: must pickle across forks)
# ---------------------------------------------------------------------------

def _phase1_shard_worker(shard_paths: list[str]) -> dict:
    """Unify one shard's profiles into a worker-local CCT — no uniquing lock;
    the shard trees meet in the parent's reduction tree (paper §4.4)."""
    structures: dict[str, StructureInfo] = {}
    tree = ContextTree()
    remaps, routes, identities, trace_lens, registries = [], [], [], [], []
    for path in shard_paths:
        prof = MeasurementProfile.load(path)
        own = _load_structures(prof, structures)
        remap, rts = expand_profile_tree(tree, prof.tree, own)
        remaps.append(remap)
        routes.append(rts)
        identities.append(prof.identity)
        trace_lens.append(int(prof.trace.time.size))
        registries.append(prof.environment.get("registry", []))
    return {"tree": tree.to_arrays(), "remaps": remaps, "routes": routes,
            "identities": identities, "trace_lens": trace_lens,
            "registries": registries}


_PHASE2_STATE: tuple[np.ndarray, np.ndarray, bool, bool] | None = None


def _phase2_init(end: np.ndarray, keep_exclusive: bool,
                 write_traces: bool) -> None:
    """Pool initializer: ship the (large) subtree-interval array — and build
    the identity position vector — once per worker instead of once per
    profile task."""
    global _PHASE2_STATE
    end = np.asarray(end, dtype=np.int64)
    _PHASE2_STATE = (end, np.arange(end.size), bool(keep_exclusive),
                     bool(write_traces))


def _phase2_profile_worker(task) -> tuple:
    """Remap + redistribute + propagate + encode one profile; ship the
    encoded plane (and per-profile statistics payload) back to the writer."""
    path, remap_final, routes_final = task
    assert _PHASE2_STATE is not None, "phase-2 worker used without initializer"
    end, ident_pos, keep_exclusive, write_traces = _PHASE2_STATE
    prof = MeasurementProfile.load(path)
    sm = prof.metrics.remap_contexts(np.asarray(remap_final, dtype=np.int64))
    if routes_final:
        sm = redistribute_placeholders(sm, routes_final)
    sm = propagate_inclusive(sm, ident_pos, end,
                             keep_exclusive=keep_exclusive)
    acc = StatsAccumulator()
    acc.update(sm)
    if write_traces and prof.trace.time.size:
        tr = prof.trace.remap_contexts(np.asarray(remap_final, dtype=np.int64))
        ttime, tctx = prof.trace.time, tr.ctx
    else:
        ttime, tctx = np.empty(0, np.float64), np.empty(0, np.uint32)
    return (sm.encode(), sm.n_contexts, sm.n_values, acc.to_arrays(),
            ttime, tctx)


# ---------------------------------------------------------------------------
# completion helpers
# ---------------------------------------------------------------------------

def _renumber(tree: ContextTree, pos: np.ndarray, order: np.ndarray) -> ContextTree:
    """Rebuild the tree with ids equal to canonical preorder positions.

    Names are re-interned in preorder encounter order so the serialized
    name table — like the ids — is a pure function of tree content, not of
    the (scheduling-dependent) order names were first seen during unification.
    """
    out = ContextTree.__new__(ContextTree)
    n = len(tree)
    out.names = []
    out._name_ids = {}
    out.parent = [-1] * n
    out.kind = [0] * n
    out.name_id = [0] * n
    for new in range(n):
        old = int(order[new])
        out.kind[new] = tree.kind[old]
        out.name_id[new] = out._intern(tree.names[tree.name_id[old]])
        out.parent[new] = -1 if old == 0 else int(pos[tree.parent[old]])
    out._children = {
        (out.parent[c], out.kind[c], out.name_id[c]): c for c in range(1, n)
    }
    return out


