"""The streaming aggregation engine (paper §4).

Dataflow (Fig. 3 of the paper): profile *sources* are streamed in parallel
by a pool of worker threads; contexts are unified and lexically expanded
("edit" + U), metric values are redistributed across reconstructed routes,
propagated to inclusive costs, accumulated into cross-profile statistics
(+), and written *as soon as they are computed* to the PMS database through
a two-buffer out-of-order writer; traces are remapped and written in
parallel at offsets precomputed by a prefix sum.  A final "completion"
writes metadata + summary statistics and generates the CMS file.

Two phases, exactly as §4.4:

* **phase 1** — parse context/identity sections, unify CCTs (the reduction
  payload in multi-rank mode);
* **phase 2** — parse metrics/traces, remap onto final context ids,
  propagate, accumulate, write.

Thread coordination notes vs the paper (§4.2): CPython serializes the
uniquing dict through one lock rather than per-subtree reader-writer locks
(GIL realities, see DESIGN.md §4); everything downstream of phase 1 —
propagation, statistics, encoding, I/O — runs without shared mutable state
(thread-local accumulators merged by a reduction tree at completion, the
"relaxed atomics" analog).
"""
from __future__ import annotations

import os
import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import cms as cms_mod
from repro.core.cct import ContextTree
from repro.core.lexical import StructureInfo, expand_profile_tree
from repro.core.pms import PMSWriter
from repro.core.propagate import propagate_inclusive, redistribute_placeholders
from repro.core.sparse import MeasurementProfile
from repro.core.stats import StatsAccumulator
from repro.core.traces import TraceDBWriter


@dataclass
class AggregationConfig:
    n_threads: int = 4
    buffer_bytes: int = 1 << 20          # PMS double-buffer flush threshold
    cms_workers: int = 4
    cms_strategy: str = "vectorized"     # or "heap" (paper-faithful merge)
    cms_balance: str = "dynamic"         # GLB (paper §4.4) or "static"
    group_target_bytes: int = 1 << 20
    write_cms: bool = True
    write_traces: bool = True
    keep_exclusive: bool = True


@dataclass
class AnalysisResult:
    pms_path: str
    cms_path: str | None
    trace_path: str | None
    n_profiles: int
    n_contexts: int
    n_values: int
    timings: dict[str, float] = field(default_factory=dict)
    sizes: dict[str, int] = field(default_factory=dict)


class _PhaseTimer:
    """Accumulates io/compute seconds across threads (Fig. 6 breakdown)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.acc: dict[str, float] = {}

    def add(self, key: str, dt: float) -> None:
        with self._lock:
            self.acc[key] = self.acc.get(key, 0.0) + dt


class TwoBufferWriter:
    """The two-buffer PMS output scheme of paper §4.3.1.

    Threads append encoded planes to the active buffer; whoever crosses the
    threshold swaps buffers (fetch-and-add allocates the file region) and
    performs the write while other threads keep appending to the twin.
    """

    def __init__(self, pms: PMSWriter, threshold: int, timer: _PhaseTimer):
        self._pms = pms
        self._threshold = threshold
        self._timer = timer
        self._pool: queue.Queue = queue.Queue()
        self._pool.put(bytearray())
        self._pool.put(bytearray())
        self._buf: bytearray = self._pool.get()
        self._recs: list[tuple[int, int, int, int, int, dict | None]] = []
        self._lock = threading.Lock()

    def append(self, pid: int, payload: bytes, n_ctx: int, n_vals: int,
               identity: dict | None = None) -> None:
        to_write = None
        with self._lock:
            off = len(self._buf)
            self._buf += payload
            self._recs.append((pid, off, len(payload), n_ctx, n_vals, identity))
            if len(self._buf) >= self._threshold:
                to_write = (self._buf, self._recs)
                # blocks only if both buffers are mid-write (backpressure)
                self._buf = self._pool.get()
                self._recs = []
        if to_write is not None:
            self._flush(*to_write)

    def _flush(self, buf: bytearray, recs) -> None:
        if not buf:
            self._recycle(buf)
            return
        region = self._pms.alloc(len(buf))
        t0 = time.perf_counter()
        self._pms.write_at(region, bytes(buf))
        self._timer.add("io_write", time.perf_counter() - t0)
        for pid, off, nb, n_ctx, n_vals, ident in recs:
            self._pms.record_plane(pid, region + off, nb, n_ctx, n_vals, ident)
        self._recycle(buf)

    def _recycle(self, buf: bytearray) -> None:
        buf.clear()
        self._pool.put(buf)

    def close(self) -> None:
        with self._lock:
            to_write = (self._buf, self._recs)
            self._buf = self._pool.get()
            self._recs = []
        self._flush(*to_write)


def _parallel_for(n_items: int, n_threads: int, body) -> None:
    """Non-blocking parallel loop over items (the custom task runtime analog,
    paper §4.2.4): workers pull indices from a shared counter."""
    counter = iter(range(n_items))
    lock = threading.Lock()
    errors: list[BaseException] = []

    def work():
        while True:
            with lock:
                i = next(counter, None)
            if i is None:
                return
            try:
                body(i)
            except BaseException as e:
                errors.append(e)
                return

    threads = [threading.Thread(target=work) for _ in range(min(n_threads, max(n_items, 1)))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


class StreamingAggregator:
    """Single-rank engine; :mod:`repro.core.reduction` composes ranks."""

    def __init__(self, out_dir, config: AggregationConfig | None = None):
        self.out_dir = str(out_dir)
        os.makedirs(self.out_dir, exist_ok=True)
        self.cfg = config or AggregationConfig()

    # -- phase 1: contexts ---------------------------------------------------
    def parse_contexts(self, profile_paths: list[str], timer: _PhaseTimer,
                       unified: ContextTree | None = None):
        """Parallel parse + unify; returns (unified, remaps, routes, meta)."""
        cfg = self.cfg
        unified = unified or ContextTree()
        structures: dict[str, StructureInfo] = {}
        struct_lock = threading.Lock()
        uniq_lock = threading.Lock()
        n = len(profile_paths)
        remaps: list[np.ndarray | None] = [None] * n
        routes: list[dict] = [{}] * n
        identities: list[dict] = [{}] * n
        trace_lens = np.zeros(n, dtype=np.int64)
        registry_jsons: list[list] = [[]] * n

        def body(i: int):
            t0 = time.perf_counter()
            prof = MeasurementProfile.load(profile_paths[i])
            timer.add("io_read", time.perf_counter() - t0)
            t1 = time.perf_counter()
            # eagerly acquire lexical info for new binaries (paper §4.2.3)
            for sp in prof.file_paths:
                if sp.endswith(".struct.json") and os.path.exists(sp):
                    with struct_lock:
                        if sp not in structures:
                            structures[sp] = StructureInfo.load(sp)
            with uniq_lock:  # uniquing (U) — see module docstring on locking
                remap, rts = expand_profile_tree(unified, prof.tree, structures)
            remaps[i] = remap
            routes[i] = rts
            identities[i] = prof.identity
            trace_lens[i] = prof.trace.time.size
            registry_jsons[i] = prof.environment.get("registry", [])
            timer.add("compute", time.perf_counter() - t1)

        _parallel_for(n, cfg.n_threads, body)
        return unified, remaps, routes, identities, trace_lens, registry_jsons

    # -- full run --------------------------------------------------------------
    def run(self, profile_paths: list[str]) -> AnalysisResult:
        cfg = self.cfg
        timer = _PhaseTimer()
        t_start = time.perf_counter()
        n = len(profile_paths)

        # ---- phase 1
        t0 = time.perf_counter()
        unified, remaps, routes, identities, trace_lens, registries = (
            self.parse_contexts(profile_paths, timer))
        # renumber contexts to preorder ids: subtree intervals become
        # contiguous and CMS context order matches tree order
        pos, order, end = unified.preorder()
        final_tree = _renumber(unified, pos, order)
        n_ctx = len(final_tree)
        timer.add("phase1", time.perf_counter() - t0)

        # ---- phase 2
        t0 = time.perf_counter()
        pms_path = os.path.join(self.out_dir, "db.pms")
        pms = PMSWriter(pms_path, n)
        writer = TwoBufferWriter(pms, cfg.buffer_bytes, timer)
        trace_path = None
        trace_writer = None
        if cfg.write_traces and trace_lens.sum() > 0:
            trace_path = os.path.join(self.out_dir, "db.trc")
            trace_writer = TraceDBWriter(trace_path, [int(x) for x in trace_lens])
        accs = [StatsAccumulator() for _ in range(cfg.n_threads)]
        idx_of_thread: dict[int, int] = {}
        tl_lock = threading.Lock()
        identity_pos = np.arange(n)
        end_arr = end  # by preorder id
        ident_pos = np.arange(n_ctx)
        n_values_total = [0]

        def body(i: int):
            t0 = time.perf_counter()
            prof = MeasurementProfile.load(profile_paths[i])
            timer.add("io_read", time.perf_counter() - t0)
            t1 = time.perf_counter()
            remap_final = pos[np.asarray(remaps[i], dtype=np.int64)]
            sm = prof.metrics.remap_contexts(remap_final)
            if routes[i]:
                rts = {int(pos[ph]): (pos[t_], w) for ph, (t_, w) in routes[i].items()}
                sm = redistribute_placeholders(sm, rts)
            sm = propagate_inclusive(sm, ident_pos, end_arr,
                                     keep_exclusive=cfg.keep_exclusive)
            tid = threading.get_ident()
            with tl_lock:
                k = idx_of_thread.setdefault(tid, len(idx_of_thread) % cfg.n_threads)
                n_values_total[0] += sm.n_values
            accs[k].update(sm)
            payload = sm.encode()
            timer.add("compute", time.perf_counter() - t1)
            writer.append(i, payload, sm.n_contexts, sm.n_values, identities[i])
            if trace_writer is not None and prof.trace.time.size:
                tr = prof.trace.remap_contexts(remap_final)
                t2 = time.perf_counter()
                trace_writer.write_trace(i, tr)
                timer.add("io_write", time.perf_counter() - t2)

        _parallel_for(n, cfg.n_threads, body)
        writer.close()
        if trace_writer is not None:
            trace_writer.close()
        timer.add("phase2", time.perf_counter() - t0)

        # ---- completion (paper: overlapped with CMS generation)
        t0 = time.perf_counter()
        root_acc = _merge_accumulators(accs)
        stats = root_acc.finalize()
        registry_json = next((r for r in registries if r), [])
        pms_bytes = pms.finalize(tree=final_tree, registry_json=registry_json,
                                 stats={k: np.asarray(v, np.float64)
                                        for k, v in stats.items()})
        cms_path = None
        cms_bytes = 0
        if cfg.write_cms:
            cms_path = os.path.join(self.out_dir, "db.cms")
            t2 = time.perf_counter()
            cms_bytes = cms_mod.build_cms(
                pms_path, cms_path, n_workers=cfg.cms_workers,
                strategy=cfg.cms_strategy, balance=cfg.cms_balance,
                group_target_bytes=cfg.group_target_bytes)
            timer.add("cms", time.perf_counter() - t2)
        timer.add("completion", time.perf_counter() - t0)
        timer.add("total", time.perf_counter() - t_start)

        sizes = {"pms": pms_bytes, "cms": cms_bytes}
        if trace_path:
            sizes["traces"] = os.path.getsize(trace_path)
        return AnalysisResult(
            pms_path=pms_path, cms_path=cms_path, trace_path=trace_path,
            n_profiles=n, n_contexts=n_ctx, n_values=n_values_total[0],
            timings=dict(timer.acc), sizes=sizes,
        )


def _renumber(tree: ContextTree, pos: np.ndarray, order: np.ndarray) -> ContextTree:
    """Rebuild the tree with ids equal to preorder positions."""
    out = ContextTree.__new__(ContextTree)
    n = len(tree)
    out.names = list(tree.names)
    out._name_ids = dict(tree._name_ids)
    out.parent = [-1] * n
    out.kind = [0] * n
    out.name_id = [tree.name_id[0]] * n
    for new in range(n):
        old = int(order[new])
        out.kind[new] = tree.kind[old]
        out.name_id[new] = tree.name_id[old]
        out.parent[new] = -1 if old == 0 else int(pos[tree.parent[old]])
    out._children = {
        (out.parent[c], out.kind[c], out.name_id[c]): c for c in range(1, n)
    }
    return out


def _merge_accumulators(accs: list[StatsAccumulator],
                        branching: int = 2) -> StatsAccumulator:
    """Reduction tree over thread-local accumulators (paper §4.4)."""
    layer = [a for a in accs if len(a) or True]
    while len(layer) > 1:
        nxt = []
        for i in range(0, len(layer), branching):
            head = layer[i]
            for other in layer[i + 1 : i + branching]:
                head.merge(other)
            nxt.append(head)
        layer = nxt
    return layer[0] if layer else StatsAccumulator()
