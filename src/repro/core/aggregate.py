"""The streaming aggregation engine (paper §4).

Dataflow (Fig. 3 of the paper): profile *sources* are streamed in parallel
by a pool of workers; contexts are unified and lexically expanded
("edit" + U), metric values are redistributed across reconstructed routes,
propagated to inclusive costs, accumulated into cross-profile statistics
(+), and written *as soon as they are computed* to the PMS database through
a two-buffer out-of-order writer; traces are remapped and written in
parallel at offsets precomputed by a prefix sum.  A final "completion"
writes metadata + summary statistics and generates the CMS file.

Two phases, exactly as §4.4:

* **phase 1** — parse context/identity sections, unify CCTs (the reduction
  payload in multi-rank mode);
* **phase 2** — parse metrics/traces, remap onto final context ids,
  propagate, accumulate, write.

Execution substrate — the :mod:`repro.runtime` backends (paper §4.2 / §4.4):

* ``serial`` / ``threads`` run both phases in-process; phase-1 uniquing
  serializes through one lock (GIL realities, see DESIGN.md §4) while
  everything downstream runs without shared mutable state;
* ``processes`` shards profiles across multiprocessing workers: each worker
  unifies a *local* CCT over its shard (no uniquing lock at all) and the
  shard trees merge up a reduction tree (§4.4 phase 1); phase-2 propagate/
  encode runs in workers, which ship encoded planes back to the parent — a
  single writer feeding :class:`TwoBufferWriter`.

**Determinism contract:** all three backends produce byte-identical PMS and
CMS databases for the same inputs and config.  Three mechanisms pin this
down: (1) ``ContextTree.preorder`` orders children canonically so final
context ids are a function of tree *content*, not insertion schedule;
(2) plane appends pass through :class:`repro.runtime.OrderedSink`, pinning
region allocation to profile order; (3) summary statistics are accumulated
per profile and folded in profile order by a streaming carry-chain reducer
whose merge shape is a pure function of the profile count, pinning the
floating-point op order with only O(log n) accumulators resident.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import cms as cms_mod
from repro.core.cct import ContextTree
from repro.core.lexical import StructureInfo, expand_profile_tree
from repro.core.pipeline import transform_plane
from repro.core.pms import PMSWriter
from repro.core.sparse import MeasurementProfile, Trace
from repro.core.stats import StatsAccumulator
from repro.core.traces import TraceDBWriter
from repro.runtime import OrderedSink, get_executor
from repro.runtime import shm as shm_mod
from repro.runtime.reduce import (AsyncStreamingReducer, StreamingReducer,
                                  TreeWithMaps, merge_tree_with_maps,
                                  tree_reduce)


@dataclass
class AggregationConfig:
    n_threads: int = 4                   # legacy knob; used when n_workers unset
    executor: str = "threads"            # serial | threads | processes | ranks
    n_workers: int | None = None         # worker count / rank count per backend
    buffer_bytes: int = 1 << 20          # PMS double-buffer flush threshold
    sink_window: int | None = None       # ordered-sink out-of-order bound for
                                         # in-process backends; None = auto
                                         # (2 x workers), 0 = unbounded
    cms_workers: int = 4
    cms_strategy: str = "vectorized"     # or "heap" (paper-faithful merge)
    cms_balance: str = "dynamic"         # GLB (paper §4.4) or "static"
    group_target_bytes: int = 1 << 20
    write_cms: bool = True
    write_traces: bool = True
    keep_exclusive: bool = True
    pipeline: str = "fused"              # fused single-sort phase-2 kernel,
                                         # or "legacy" (three-pass chain);
                                         # byte-identical outputs either way
    plane_transport: str = "shm"         # processes backend: "shm" slab
                                         # arena or "pickle" through the
                                         # pool pipe; byte-identical outputs
    shm_slab_bytes: int = 1 << 20        # slab size; bigger planes fall
                                         # back to one-shot segments
    compute: str = "cpu"                 # "cpu" numpy hot loops, or "device"
                                         # — route phase-2 propagation /
                                         # combine / CMS scans through the
                                         # Pallas kernels (ROADMAP item 3);
                                         # falls back to cpu when no
                                         # accelerator is attached
    device_interpret: bool = False       # let compute="device" run on the
                                         # interpret-mode kernel proxy when
                                         # no accelerator exists (tests /
                                         # benches; slow, but exercises the
                                         # real kernel bodies)
    stats_merge: str = "auto"            # cross-profile stats carry-chain:
                                         # "inline" on the consume thread,
                                         # "workers" on a small merge pool
                                         # (byte-identical fold shape), or
                                         # "auto" = workers iff workers > 1

    @property
    def workers(self) -> int:
        return max(1, self.n_threads if self.n_workers is None else self.n_workers)

    def effective_compute(self) -> str:
        """The backend that will actually run: ``"device"`` only when the
        kernels can execute here (accelerator attached, or the interpret
        proxy explicitly allowed) — otherwise silently ``"cpu"``, so one
        config deploys unchanged on accelerator and plain hosts."""
        if self.compute != "device":
            return "cpu"
        from repro.kernels import batch
        return "device" if batch.device_ok(self.device_interpret) else "cpu"

    def resolved_stats_merge(self) -> str:
        if self.stats_merge != "auto":
            return self.stats_merge
        return "workers" if self.workers > 1 else "inline"

    @property
    def effective_sink_window(self) -> int | None:
        """Out-of-order plane budget for the in-process ordered sink.

        ``None`` (unbounded) only when explicitly requested with 0; the
        default bounds residency at 2x the worker count — enough slack that
        workers rarely stall, small enough that a slow profile 0 cannot
        force O(n_profiles) encoded planes to buffer (ROADMAP known limit).
        """
        if self.sink_window is None:
            return max(2 * self.workers, 2)
        return self.sink_window if self.sink_window > 0 else None


@dataclass
class AnalysisResult:
    pms_path: str
    cms_path: str | None
    trace_path: str | None
    n_profiles: int
    n_contexts: int
    n_values: int
    timings: dict[str, float] = field(default_factory=dict)
    sizes: dict[str, int] = field(default_factory=dict)


class _PhaseTimer:
    """Accumulates io/compute seconds across threads (Fig. 6 breakdown)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.acc: dict[str, float] = {}

    def add(self, key: str, dt: float) -> None:
        with self._lock:
            self.acc[key] = self.acc.get(key, 0.0) + dt


class TwoBufferWriter:
    """The two-buffer PMS output scheme of paper §4.3.1.

    Threads append encoded planes to the active buffer; whoever crosses the
    threshold swaps buffers (fetch-and-add allocates the file region) and
    performs the write while other threads keep appending to the twin.
    """

    def __init__(self, pms: PMSWriter, threshold: int, timer: _PhaseTimer):
        self._pms = pms
        self._threshold = threshold
        self._timer = timer
        self._pool: queue.Queue = queue.Queue()
        self._pool.put(bytearray())
        self._pool.put(bytearray())
        self._buf: bytearray = self._pool.get()
        self._recs: list[tuple[int, int, int, int, int, dict | None]] = []
        self._lock = threading.Lock()

    def append(self, pid: int, payload: bytes, n_ctx: int, n_vals: int,
               identity: dict | None = None) -> None:
        to_write = None
        with self._lock:
            off = len(self._buf)
            self._buf += payload
            self._recs.append((pid, off, len(payload), n_ctx, n_vals, identity))
            if len(self._buf) >= self._threshold:
                to_write = (self._buf, self._recs)
                # blocks only if both buffers are mid-write (backpressure)
                self._buf = self._pool.get()
                self._recs = []
        if to_write is not None:
            self._flush(*to_write)

    def _flush(self, buf: bytearray, recs) -> None:
        if not buf:
            self._recycle(buf)
            return
        region = self._pms.alloc(len(buf))
        t0 = time.perf_counter()
        self._pms.write_at(region, bytes(buf))
        self._timer.add("io_write", time.perf_counter() - t0)
        for pid, off, nb, n_ctx, n_vals, ident in recs:
            self._pms.record_plane(pid, region + off, nb, n_ctx, n_vals, ident)
        self._recycle(buf)

    def _recycle(self, buf: bytearray) -> None:
        buf.clear()
        self._pool.put(buf)

    def close(self) -> None:
        with self._lock:
            to_write = (self._buf, self._recs)
            self._buf = self._pool.get()
            self._recs = []
        self._flush(*to_write)


def _load_structures(prof: MeasurementProfile,
                     cache: dict[str, StructureInfo],
                     lock: threading.Lock | None = None
                     ) -> dict[str, StructureInfo]:
    """Eagerly acquire lexical info for the profile's binaries (paper §4.2.3)
    and return the subset visible to this profile: exactly the structure
    files named in its file-paths section.  Restricting visibility per
    profile (instead of handing every profile the whole shared cache) keeps
    the expansion a pure function of the profile — required for
    cross-executor determinism, so every phase-1 path must go through this
    one helper.

    With ``lock``, the cache is shared between threads: disk I/O happens
    *outside* the lock and only cache lookups/publication run under it —
    holding a lock across file reads would serialize every thread's phase 1
    behind the slowest disk access.  Two threads may race to load the same
    file; ``setdefault`` keeps the first copy (the loads are pure functions
    of the file, so either copy is equivalent).
    """
    want = [sp for sp in prof.file_paths
            if sp.endswith(".struct.json") and os.path.exists(sp)]
    if lock is None:
        for sp in want:
            if sp not in cache:
                cache[sp] = StructureInfo.load(sp)
        return {sp: cache[sp] for sp in prof.file_paths if sp in cache}
    with lock:
        missing = [sp for sp in want if sp not in cache]
    loaded = [(sp, StructureInfo.load(sp)) for sp in missing]  # I/O unlocked
    with lock:
        for sp, si in loaded:
            cache.setdefault(sp, si)
        return {sp: cache[sp] for sp in prof.file_paths if sp in cache}


def _merge_stats(a: StatsAccumulator, b: StatsAccumulator) -> StatsAccumulator:
    a.merge(b)
    return a


def _make_stats_reducer(cfg: AggregationConfig):
    """The cross-profile statistics fold: same carry-chain shape either way
    (byte-identical results), ``"workers"`` just runs the merges on a small
    pool instead of the consume thread (ROADMAP item 3 — the sharded path's
    parent-side merge bottleneck)."""
    if cfg.resolved_stats_merge() == "workers":
        return AsyncStreamingReducer(_merge_stats, n_threads=2)
    return StreamingReducer(_merge_stats)


class StreamingAggregator:
    """Single-rank engine; :mod:`repro.core.reduction` composes ranks."""

    def __init__(self, out_dir, config: AggregationConfig | None = None):
        self.out_dir = str(out_dir)
        os.makedirs(self.out_dir, exist_ok=True)
        self.cfg = config or AggregationConfig()

    def _executor(self):
        kwargs = {}
        if (self.cfg.executor == "processes"
                and self.cfg.effective_compute() == "device"
                and not os.environ.get("REPRO_MP_CONTEXT")):
            # forking after XLA initializes in the parent can deadlock the
            # children; spawn workers get a clean runtime.  An explicit
            # REPRO_MP_CONTEXT still wins.
            kwargs["mp_context"] = "spawn"
        return get_executor(self.cfg.executor, self.cfg.workers, **kwargs)

    # -- phase 1: contexts ---------------------------------------------------
    def parse_contexts(self, profile_paths: list[str], timer: _PhaseTimer,
                       unified: ContextTree | None = None, executor=None):
        """Parallel parse + unify; returns (unified, remaps, routes, meta).

        In-process only (the body closes over the shared tree); the
        ``processes`` backend goes through :func:`_phase1_shard_worker`.
        """
        ex = executor or get_executor(self.cfg.executor, self.cfg.workers)
        return phase1_unify_inprocess(profile_paths, timer, unified=unified,
                                      executor=ex)

    # -- full run --------------------------------------------------------------
    def run(self, profile_paths: list[str]) -> AnalysisResult:
        if self.cfg.pipeline not in ("fused", "legacy"):
            raise ValueError(f"unknown pipeline {self.cfg.pipeline!r}; "
                             f"expected 'fused' or 'legacy'")
        if self.cfg.plane_transport not in ("shm", "pickle"):
            raise ValueError(f"unknown plane_transport "
                             f"{self.cfg.plane_transport!r}; expected 'shm' "
                             f"or 'pickle'")
        if self.cfg.compute not in ("cpu", "device"):
            raise ValueError(f"unknown compute {self.cfg.compute!r}; "
                             f"expected 'cpu' or 'device'")
        if self.cfg.stats_merge not in ("auto", "inline", "workers"):
            raise ValueError(f"unknown stats_merge {self.cfg.stats_merge!r}; "
                             f"expected 'auto', 'inline' or 'workers'")
        if self.cfg.compute == "device" and self.cfg.pipeline == "legacy":
            raise ValueError("compute='device' requires pipeline='fused'; "
                             "the legacy three-pass chain has no device path")
        if self.cfg.compute == "device" and self.cfg.executor == "ranks":
            raise ValueError("compute='device' is not supported under the "
                             "ranks driver; use serial/threads/processes")
        with self._executor() as ex:
            if ex.driver == "ranks":
                # whole-run driver backend (paper §4.4): n_workers ranks,
                # n_threads threads per rank; imported lazily — the rank
                # driver composes *this* engine, so the import must not be
                # circular at module load
                from repro.core.reduction import aggregate_multiprocess
                return aggregate_multiprocess(
                    profile_paths, self.out_dir, n_ranks=ex.n_workers,
                    threads_per_rank=self.cfg.n_threads, config=self.cfg)
            if ex.in_process:
                return self._run_inprocess(profile_paths, ex)
            return self._run_sharded(profile_paths, ex)

    # -- in-process path (serial / threads) ------------------------------------
    def _run_inprocess(self, profile_paths: list[str], ex) -> AnalysisResult:
        cfg = self.cfg
        timer = _PhaseTimer()
        t_start = time.perf_counter()
        n = len(profile_paths)

        # ---- phase 1
        t0 = time.perf_counter()
        unified, remaps, routes, identities, trace_lens, registries = (
            self.parse_contexts(profile_paths, timer, executor=ex))
        # renumber contexts to canonical preorder ids: subtree intervals
        # become contiguous and CMS context order matches tree order
        pos, order, end = unified.preorder()
        final_tree = _renumber(unified, pos, order)
        n_ctx = len(final_tree)
        timer.add("phase1", time.perf_counter() - t0)

        # ---- phase 2
        t0 = time.perf_counter()
        pms_path = os.path.join(self.out_dir, "db.pms")
        pms = PMSWriter(pms_path, n)
        writer = TwoBufferWriter(pms, cfg.buffer_bytes, timer)
        # stats fold inside the ordered sink: in profile order with a shape
        # that is a pure function of n, and only O(log n) accumulators live
        stats_reducer = _make_stats_reducer(cfg)
        trace_path = None
        trace_writer = None
        if cfg.write_traces and trace_lens.sum() > 0:
            trace_path = os.path.join(self.out_dir, "db.trc")
            trace_writer = TraceDBWriter(trace_path, [int(x) for x in trace_lens])
        nvals = np.zeros(n, dtype=np.int64)
        parent_pre = np.asarray(final_tree.parent, dtype=np.int64)

        def consume(i: int, payload, p_ctx: int, p_vals: int, acc) -> None:
            # in-order append: pins region allocation to profile order
            writer.append(i, payload, p_ctx, p_vals, identities[i])
            stats_reducer.push(acc)
            nvals[i] = p_vals

        trace_sink = None
        if trace_writer is not None:
            def trace_sink(i: int, tr: Trace) -> None:
                t2 = time.perf_counter()
                trace_writer.write_trace(i, tr)
                timer.add("io_write", time.perf_counter() - t2)

        try:
            phase2_stream_inprocess(
                profile_paths,
                lambda i: pos[np.asarray(remaps[i], dtype=np.int64)],
                lambda i: {int(pos[ph]): (pos[t_], w)
                           for ph, (t_, w) in routes[i].items()},
                cfg, ex, parent_pre, end, timer, consume, trace_sink)
            writer.close()
        except BaseException:
            stats_reducer.close()
            pms.abort()
            if trace_writer is not None:
                trace_writer.close()
            raise
        if trace_writer is not None:
            trace_writer.close()
        timer.add("phase2", time.perf_counter() - t0)

        return self._complete(pms, final_tree, stats_reducer.result(),
                              registries, trace_path, timer, t_start, n,
                              n_ctx, int(nvals.sum()))

    # -- sharded path (processes) ----------------------------------------------
    def _run_sharded(self, profile_paths: list[str], ex) -> AnalysisResult:
        cfg = self.cfg
        timer = _PhaseTimer()
        t_start = time.perf_counter()
        n = len(profile_paths)
        shards = ex.shards(n)

        # ---- phase 1: per-shard local CCTs, merged by a reduction tree ----
        t0 = time.perf_counter()
        shard_paths = [[profile_paths[i] for i in sh] for sh in shards]
        results1: dict[int, dict] = dict(
            ex.map_unordered(_phase1_shard_worker, shard_paths))
        items = [
            TreeWithMaps(ContextTree.from_arrays(results1[k]["tree"]),
                         {k: np.arange(len(results1[k]["tree"]["parent"]))})
            for k in range(len(shards))
        ]
        if items:
            merged, _ = tree_reduce(items, merge_tree_with_maps, 2)
        else:
            merged = TreeWithMaps(ContextTree(), {})
        pos, order, end = merged.tree.preorder()
        final_tree = _renumber(merged.tree, pos, order)
        n_ctx = len(final_tree)

        # broadcast final ids back: compose per-profile remaps and routes
        # (fresh containers per index — never `[{}] * n` aliases)
        remaps_final: list[np.ndarray | None] = [None] * n
        routes_final: list[dict] = [{} for _ in range(n)]
        identities: list[dict | None] = [None] * n
        registries: list[list] = [[] for _ in range(n)]
        trace_lens = np.zeros(n, dtype=np.int64)
        for k, sh in enumerate(shards):
            res = results1[k]
            shard_map = pos[merged.maps[k]]  # local ctx -> final preorder id
            for j, g in enumerate(sh):
                remaps_final[g] = shard_map[np.asarray(res["remaps"][j], np.int64)]
                routes_final[g] = {
                    int(shard_map[ph]): (shard_map[np.asarray(t_, np.int64)], w)
                    for ph, (t_, w) in res["routes"][j].items()
                }
                identities[g] = res["identities"][j]
                registries[g] = res["registries"][j]
                trace_lens[g] = res["trace_lens"][j]
        timer.add("phase1", time.perf_counter() - t0)

        # ---- phase 2: propagate/encode in workers, single writer here ----
        t0 = time.perf_counter()
        pms_path = os.path.join(self.out_dir, "db.pms")
        pms = PMSWriter(pms_path, n)
        writer = TwoBufferWriter(pms, cfg.buffer_bytes, timer)
        trace_path = None
        trace_writer = None
        if cfg.write_traces and trace_lens.sum() > 0:
            trace_path = os.path.join(self.out_dir, "db.trc")
            trace_writer = TraceDBWriter(trace_path, [int(x) for x in trace_lens])
        stats_reducer = _make_stats_reducer(cfg)
        nvals = np.zeros(n, dtype=np.int64)
        parent_pre = np.asarray(final_tree.parent, dtype=np.int64)

        def consume(i: int, payload, p_ctx: int, p_vals: int, acc) -> None:
            writer.append(i, payload, p_ctx, p_vals, identities[i])
            stats_reducer.push(acc)
            nvals[i] = p_vals

        trace_sink = None
        if trace_writer is not None:
            def trace_sink(i: int, tr: Trace) -> None:
                t2 = time.perf_counter()
                trace_writer.write_trace(i, tr)
                timer.add("io_write", time.perf_counter() - t2)

        try:
            phase2_stream_sharded(profile_paths, remaps_final, routes_final,
                                  cfg, ex, parent_pre, end, timer, consume,
                                  trace_sink)
            writer.close()
        except BaseException:
            stats_reducer.close()
            pms.abort()
            if trace_writer is not None:
                trace_writer.close()
            raise
        if trace_writer is not None:
            trace_writer.close()
        timer.add("phase2", time.perf_counter() - t0)

        return self._complete(pms, final_tree, stats_reducer.result(),
                              registries, trace_path, timer, t_start, n,
                              n_ctx, int(nvals.sum()))

    # -- completion (paper: overlapped with CMS generation) --------------------
    def _complete(self, pms, final_tree, root_acc, registries,
                  trace_path, timer, t_start, n, n_ctx, n_values) -> AnalysisResult:
        cfg = self.cfg
        t0 = time.perf_counter()
        if root_acc is None:
            root_acc = StatsAccumulator()
        stats = root_acc.finalize()
        registry_json = next((r for r in registries if r), [])
        pms_bytes = pms.finalize(tree=final_tree, registry_json=registry_json,
                                 stats={k: np.asarray(v, np.float64)
                                        for k, v in stats.items()})
        cms_path = None
        cms_bytes = 0
        if cfg.write_cms:
            cms_path = os.path.join(self.out_dir, "db.cms")
            t2 = time.perf_counter()
            cms_bytes = cms_mod.build_cms(
                pms.path, cms_path, n_workers=cfg.cms_workers,
                strategy=cfg.cms_strategy, balance=cfg.cms_balance,
                group_target_bytes=cfg.group_target_bytes,
                executor=cfg.executor, compute=cfg.effective_compute())
            timer.add("cms", time.perf_counter() - t2)
        timer.add("completion", time.perf_counter() - t0)
        timer.add("total", time.perf_counter() - t_start)

        sizes = {"pms": pms_bytes, "cms": cms_bytes}
        if trace_path:
            sizes["traces"] = os.path.getsize(trace_path)
        return AnalysisResult(
            pms_path=pms.path, cms_path=cms_path, trace_path=trace_path,
            n_profiles=n, n_contexts=n_ctx, n_values=n_values,
            timings=dict(timer.acc), sizes=sizes,
        )


# ---------------------------------------------------------------------------
# phase-1 / phase-2 streaming engines (shared by one-shot runs and live
# ingest appends)
# ---------------------------------------------------------------------------

def phase1_unify_inprocess(profile_paths: list[str], timer: _PhaseTimer,
                           unified: ContextTree | None = None, executor=None):
    """Parallel parse + unify into ``unified`` (grown in place when given —
    the live-ingest append path; a one-shot run starts from an empty tree).
    Returns ``(unified, remaps, routes, identities, trace_lens,
    registry_jsons)`` with remaps/routes in *creation-order* ids of the
    unified tree: stable under later appends, renumbered to canonical
    preorder only when a database is written.

    In-process only (the body closes over the shared tree); the
    ``processes`` backend goes through :func:`_phase1_shard_worker`.
    """
    ex = executor or get_executor("serial", 1)
    if not ex.in_process:
        raise ValueError(
            f"phase1_unify_inprocess requires an in-process executor, got "
            f"{ex.name!r}; use StreamingAggregator.run for the sharded "
            f"path, or pass executor= explicitly")
    unified = unified if unified is not None else ContextTree()
    structures: dict[str, StructureInfo] = {}
    struct_lock = threading.Lock()
    uniq_lock = threading.Lock()
    n = len(profile_paths)
    # one fresh container per index — a shared `[{}] * n` alias would let
    # any in-place mutation silently corrupt every profile's entry
    remaps: list[np.ndarray | None] = [None] * n
    routes: list[dict] = [{} for _ in range(n)]
    identities: list[dict] = [{} for _ in range(n)]
    trace_lens = np.zeros(n, dtype=np.int64)
    registry_jsons: list[list] = [[] for _ in range(n)]

    def body(i: int):
        t0 = time.perf_counter()
        prof = MeasurementProfile.load(profile_paths[i])
        timer.add("io_read", time.perf_counter() - t0)
        t1 = time.perf_counter()
        own = _load_structures(prof, structures, struct_lock)
        with uniq_lock:  # uniquing (U) — see module docstring on locking
            remap, rts = expand_profile_tree(unified, prof.tree, own)
        remaps[i] = remap
        routes[i] = rts
        identities[i] = prof.identity
        trace_lens[i] = prof.trace.time.size
        registry_jsons[i] = prof.environment.get("registry", [])
        timer.add("compute", time.perf_counter() - t1)

    ex.parallel_for(n, body)
    return unified, remaps, routes, identities, trace_lens, registry_jsons

def transform_profile(prof: MeasurementProfile, remap_final, routes_final,
                      parent_pre: np.ndarray, end_arr: np.ndarray, *,
                      pipeline: str, keep_exclusive: bool, want_trace: bool,
                      device=None):
    """Phase-2 compute for one loaded profile: remap + redistribute +
    propagate (the paper's edit/redistribute/propagate chain) plus the
    per-profile statistics leaf.  Returns ``(sm, acc, trace_or_None)``.

    This is *the* unit of work both execution substrates run — in worker
    threads for the in-process path, in pool processes for the sharded
    path — so the byte-determinism contract only has to be argued once.
    ``device`` is a :class:`repro.kernels.batch.DeviceAggregator` routing
    the combine/propagate hot loops through the Pallas kernels, or None for
    the pure-numpy path.
    """
    remap_arr = np.asarray(remap_final, dtype=np.int64)
    sm = transform_plane(prof.metrics, remap_arr, routes_final, parent_pre,
                         end_arr, pipeline=pipeline,
                         keep_exclusive=keep_exclusive, device=device)
    acc = StatsAccumulator()
    acc.update(sm)
    tr = (prof.trace.remap_contexts(remap_arr)
          if want_trace and prof.trace.time.size else None)
    return sm, acc, tr


def phase2_stream_inprocess(profile_paths: list[str], remap_of, route_of,
                            cfg: AggregationConfig, ex, parent_pre: np.ndarray,
                            end_arr: np.ndarray, timer: _PhaseTimer, consume,
                            trace_sink=None, device=None):
    """Stream phase 2 through an in-process executor with pluggable output
    hooks — the engine behind :meth:`StreamingAggregator._run_inprocess`
    (hooks feed the PMS/trace writers) and the live ingest tier's
    incremental append (hooks retain relabeled planes in memory).

    ``remap_of(i)`` / ``route_of(i)`` produce profile ``i``'s final context
    remap and route table (composed lazily, on the worker).  ``consume(i,
    payload, n_ctx, n_vals, acc)`` runs in profile order under an
    :class:`OrderedSink` — the determinism pin for region allocation and
    the stats carry chain; a bounded window blocks producers of far-ahead
    profiles instead of stacking encoded planes.  ``trace_sink(i, trace)``
    runs on worker threads as soon as a profile's trace is remapped.
    Returns the sink (``max_pending`` observability).

    ``device=None`` with ``cfg.effective_compute() == "device"`` builds a
    :class:`repro.kernels.batch.DeviceAggregator` for this run; worker
    threads then coalesce their propagation work into shared launches (and
    the jax dispatch releases the GIL — the ``threads`` backend's hot-loop
    rescue, ROADMAP item 3).
    """
    n = len(profile_paths)
    if device is None and cfg.effective_compute() == "device":
        from repro.kernels.batch import DeviceAggregator
        device = DeviceAggregator(end_arr)
    sink = OrderedSink(lambda i, item: consume(i, *item),
                       window=cfg.effective_sink_window)

    def body(i: int):
        try:
            t0 = time.perf_counter()
            prof = MeasurementProfile.load(profile_paths[i])
            timer.add("io_read", time.perf_counter() - t0)
            t1 = time.perf_counter()
            sm, acc, tr = transform_profile(
                prof, remap_of(i), route_of(i), parent_pre, end_arr,
                pipeline=cfg.pipeline, keep_exclusive=cfg.keep_exclusive,
                want_trace=trace_sink is not None, device=device)
            payload = sm.encode()
            timer.add("compute", time.perf_counter() - t1)
            sink.put(i, (payload, sm.n_contexts, sm.n_values, acc))
            if tr is not None:
                trace_sink(i, tr)
        except BaseException as e:
            sink.fail(e)  # wake producers blocked on the bounded window
            raise

    ex.parallel_for(n, body)
    sink.close()
    timer.add("sink_peak", float(sink.max_pending))
    if device is not None:
        timer.add("device_launches", float(device.launches))
        timer.add("device_requests", float(device.requests))
    return sink


def phase2_stream_sharded(profile_paths: list[str], remaps_final,
                          routes_final, cfg: AggregationConfig, ex,
                          parent_pre: np.ndarray, end_arr: np.ndarray,
                          timer: _PhaseTimer, consume, trace_sink=None):
    """Phase-2 streaming over a ``processes`` executor with pluggable
    output hooks: propagate/encode runs in pool workers (shm slab arena or
    pickle transport), then ``consume(i, payload, n_ctx, n_vals, acc)``
    and ``trace_sink(i, trace)`` run in profile order on the consuming
    thread.  ``payload`` and the trace arrays may be views into a shm slab
    that is recycled when the hook returns — hooks must copy anything they
    retain (the PMS writer copies into its buffer; the ingest tier copies
    into its resident planes).

    Submission credits bound in-flight profiles (worker-resident or
    buffered out of order in the sink) to the sink window; with the shm
    transport the window doubles as the slab count, so slab recycling *is*
    the submission throttle and the single-producer feed below can never
    block on its own bounded sink (the next-expected profile is always
    already submitted).  An explicit ``sink_window=0`` ("unbounded") stays
    unthrottled on the pickle transport, where no slab scarcity requires a
    bound.
    """
    n = len(profile_paths)
    window = cfg.effective_sink_window
    n_slabs = window if window is not None else max(2 * cfg.workers, 2)
    arena = None
    transport = cfg.plane_transport
    if transport == "shm" and n > 0:
        try:
            arena = shm_mod.SlabArena(n_slabs, cfg.shm_slab_bytes)
        except Exception:
            transport = "pickle"  # no usable /dev/shm: fall back
    n_credits = (window if window is not None
                 else n_slabs if arena is not None else None)

    def _consume(i: int, item):
        try:
            payload, p_ctx, p_vals, stat_arrays, ttime, tctx, cleanup = (
                _open_plane_result(item, arena))
        except BaseException:
            _discard_plane_result(item)
            raise
        try:
            consume(i, payload, p_ctx, p_vals,
                    StatsAccumulator.from_arrays(stat_arrays))
            if trace_sink is not None and len(ttime):
                trace_sink(i, Trace(ttime, tctx))
        finally:
            # on success *and* failure: release slab views, then
            # recycle the slab / unlink the one-shot segment — a
            # consume error must not strand its own descriptor (the
            # sink popped it, so the abort sweep can't see it)
            del payload, ttime, tctx
            cleanup()

    sink = OrderedSink(_consume, window=window)
    initargs = (end_arr, parent_pre, cfg.keep_exclusive, cfg.write_traces,
                cfg.pipeline, cfg.shm_slab_bytes, cfg.effective_compute())

    def task_source():
        # pulled lazily by map_throttled, one task per credit: with the
        # shm transport a free slab is guaranteed at every pull
        for i in range(n):
            slab = arena.acquire() if arena is not None else None
            yield (profile_paths[i], remaps_final[i], routes_final[i], slab)

    credits = ((lambda: sink.consumed + n_credits)
               if n_credits is not None else (lambda: float("inf")))
    try:
        for i, result in ex.map_throttled(
                _phase2_profile_worker, task_source(), credits=credits,
                initializer=_phase2_init, initargs=initargs,
                on_discard=lambda res: _discard_plane_result(res[1])):
            sink.put(i, result)
        sink.close()
    except BaseException:
        # unlink one-shot segments stranded in the sink's buffer (slabs
        # themselves die with the arena below)
        for item in sink.pending_items():
            _discard_plane_result(item)
        raise
    finally:
        if arena is not None:
            arena.close()
    timer.add("sink_peak", float(sink.max_pending))
    return sink


# ---------------------------------------------------------------------------
# process-backend worker bodies (module-level: must pickle across forks)
# ---------------------------------------------------------------------------

def _phase1_shard_worker(shard_paths: list[str]) -> dict:
    """Unify one shard's profiles into a worker-local CCT — no uniquing lock;
    the shard trees meet in the parent's reduction tree (paper §4.4)."""
    structures: dict[str, StructureInfo] = {}
    tree = ContextTree()
    remaps, routes, identities, trace_lens, registries = [], [], [], [], []
    for path in shard_paths:
        prof = MeasurementProfile.load(path)
        own = _load_structures(prof, structures)
        remap, rts = expand_profile_tree(tree, prof.tree, own)
        remaps.append(remap)
        routes.append(rts)
        identities.append(prof.identity)
        trace_lens.append(int(prof.trace.time.size))
        registries.append(prof.environment.get("registry", []))
    return {"tree": tree.to_arrays(), "remaps": remaps, "routes": routes,
            "identities": identities, "trace_lens": trace_lens,
            "registries": registries}


_PHASE2_STATE: tuple | None = None

_STAT_FIELDS = ("keys", "sum", "cnt", "vmin", "vmax", "sumsq")


def _phase2_init(end: np.ndarray, parent: np.ndarray, keep_exclusive: bool,
                 write_traces: bool, pipeline: str, slab_bytes: int,
                 compute: str = "cpu") -> None:
    """Pool initializer: ship the (large) preorder-interval arrays once per
    worker instead of once per profile task.  With ``compute="device"``
    each worker builds its own :class:`DeviceAggregator` — workers are
    single-threaded, so batches degenerate to size 1, but batch-composition
    independence makes the arithmetic (and the bytes) identical."""
    global _PHASE2_STATE
    device = None
    if compute == "device":
        from repro.kernels.batch import DeviceAggregator
        device = DeviceAggregator(np.asarray(end, dtype=np.int64))
    _PHASE2_STATE = (np.asarray(end, dtype=np.int64),
                     np.asarray(parent, dtype=np.int64),
                     bool(keep_exclusive), bool(write_traces), pipeline,
                     int(slab_bytes), device)


def _plane_section_lengths(nb_payload: int, n_trace: int,
                           n_stats: int) -> list[int]:
    """Byte lengths of a slab's sections: encoded plane, trace time (f64),
    trace ctx (u32), then the six statistics arrays (u64 keys + 5 x f64)."""
    return [nb_payload, 8 * n_trace, 4 * n_trace,
            8 * n_stats, 8 * n_stats, 8 * n_stats,
            8 * n_stats, 8 * n_stats, 8 * n_stats]


def _phase2_profile_worker(task) -> tuple:
    """Remap + redistribute + propagate + encode one profile; ship the
    encoded plane (and per-profile trace/statistics payload) back to the
    writer — through the assigned shared-memory slab when one is given
    (``("shm", ...)`` descriptor), else pickled inline (``("raw", ...)``).
    """
    path, remap_final, routes_final, slab_name = task
    # Chaos hook: the worker-death liveness tests SIGKILL a worker
    # mid-batch via the environment, which — unlike a monkeypatched worker
    # body — reaches spawn-context children (the default pool context for
    # compute="device").
    _marker = os.environ.get("REPRO_CHAOS_KILL_MARKER")
    if _marker and _marker in str(path):
        import signal
        os.kill(os.getpid(), signal.SIGKILL)
    assert _PHASE2_STATE is not None, "phase-2 worker used without initializer"
    (end, parent, keep_exclusive, write_traces, pipeline,
     slab_bytes, device) = _PHASE2_STATE
    prof = MeasurementProfile.load(path)
    sm, acc, tr = transform_profile(prof, remap_final, routes_final, parent,
                                    end, pipeline=pipeline,
                                    keep_exclusive=keep_exclusive,
                                    want_trace=write_traces, device=device)
    if tr is not None:
        ttime, tctx = tr.time, tr.ctx
    else:
        ttime, tctx = np.empty(0, np.float64), np.empty(0, np.uint32)

    if slab_name is None:
        return ("raw", sm.encode(), sm.n_contexts, sm.n_values,
                acc.to_arrays(), ttime, tctx)

    stats = acc.to_arrays()
    nb_payload = sm.encoded_nbytes()
    n_stats = int(stats["keys"].size)
    offs, total = shm_mod.sections_layout(
        _plane_section_lengths(nb_payload, int(ttime.size), n_stats))
    own = None
    if total <= slab_bytes:
        seg = shm_mod.worker_slab(slab_name)
    else:
        seg = shm_mod.create_segment(total)   # oversize plane: one-shot
        own = seg.name
    buf = seg.buf
    sm.encode_into(buf, offs[0])
    shm_mod.write_section(buf, offs[1], ttime)
    shm_mod.write_section(buf, offs[2], tctx)
    for off, field_name in zip(offs[3:], _STAT_FIELDS):
        shm_mod.write_section(buf, off, stats[field_name])
    if own is not None:
        del buf
        seg.close()  # parent attaches by name and unlinks after consuming
    return ("shm", slab_name, own, nb_payload, int(ttime.size), n_stats,
            sm.n_contexts, sm.n_values)


def _open_plane_result(item: tuple, arena):
    """Resolve a phase-2 result descriptor into (payload, n_ctx, n_vals,
    stat_arrays, ttime, tctx, cleanup).

    ``raw`` items are self-contained.  ``shm`` items resolve to zero-copy
    views over the slab (or one-shot segment); statistics arrays are copied
    out because the stats reducer holds them past slab recycling, while the
    payload/trace views are consumed (written to disk) before ``cleanup()``
    recycles the slab.
    """
    if item[0] == "raw":
        _, payload, p_ctx, p_vals, stat_arrays, ttime, tctx = item
        return payload, p_ctx, p_vals, stat_arrays, ttime, tctx, lambda: None
    _, slab_name, own, nb_payload, n_trace, n_stats, p_ctx, p_vals = item
    offs, _ = shm_mod.sections_layout(
        _plane_section_lengths(nb_payload, n_trace, n_stats))
    seg = shm_mod.attach(own) if own is not None else None
    buf = seg.buf if seg is not None else arena.view(slab_name)
    payload = buf[offs[0]:offs[0] + nb_payload]
    ttime = shm_mod.read_section(buf, offs[1], np.float64, n_trace)
    tctx = shm_mod.read_section(buf, offs[2], np.uint32, n_trace)
    stat_arrays = {
        f: shm_mod.read_section(buf, off, np.uint64 if f == "keys"
                                else np.float64, n_stats, copy=True)
        for off, f in zip(offs[3:], _STAT_FIELDS)
    }

    def cleanup():
        if seg is not None:
            shm_mod.destroy_segment(seg)
        arena.release(slab_name)

    return payload, p_ctx, p_vals, stat_arrays, ttime, tctx, cleanup


def _discard_plane_result(item) -> None:
    """Abort-path disposal of an unconsumed descriptor: unlink its one-shot
    segment if it has one (arena slabs are unlinked wholesale)."""
    if isinstance(item, tuple) and len(item) > 2 and item[0] == "shm" \
            and item[2] is not None:
        try:
            shm_mod.destroy_segment(shm_mod.attach(item[2]))
        except Exception:
            pass


# ---------------------------------------------------------------------------
# completion helpers
# ---------------------------------------------------------------------------

def _renumber(tree: ContextTree, pos: np.ndarray, order: np.ndarray) -> ContextTree:
    """Rebuild the tree with ids equal to canonical preorder positions.

    Names are re-interned in preorder encounter order so the serialized
    name table — like the ids — is a pure function of tree content, not of
    the (scheduling-dependent) order names were first seen during unification.
    """
    out = ContextTree.__new__(ContextTree)
    n = len(tree)
    out.names = []
    out._name_ids = {}
    out.parent = [-1] * n
    out.kind = [0] * n
    out.name_id = [0] * n
    for new in range(n):
        old = int(order[new])
        out.kind[new] = tree.kind[old]
        out.name_id[new] = out._intern(tree.names[tree.name_id[old]])
        out.parent[new] = -1 if old == 0 else int(pos[tree.parent[old]])
    out._children = {
        (out.parent[c], out.kind[c], out.name_id[c]): c for c in range(1, n)
    }
    return out


