"""Metric registry and semantics.

The paper (§3, §4.1.2) distinguishes *exclusive* metrics (recorded by the
measurement subsystem, attributed to a single context) from *inclusive*
metrics (computed during analysis by propagating exclusive values up the
context tree).  Analysis results therefore carry roughly twice as many
metrics as measurements (paper Table 2: "the number of metrics increases as
inclusive metrics are computed").

Metric ids are uint16.  The inclusive variant of exclusive metric ``m`` is
``m | INCLUSIVE_BIT``.  Statistic ids (sum/count/mean/min/max/std over
profiles, §4.1.2) are tracked separately by :mod:`repro.core.stats`.

Heterogeneity: host-side metrics (step wall time, input-pipeline time, ...)
apply only to host contexts; device-side metrics (flops, HBM/ICI bytes,
stall classes, per-expert load, ...) apply only to device-stream contexts.
This is the TPU analog of the paper's CPU-vs-GPU metric sparsity.
"""
from __future__ import annotations

from dataclasses import dataclass

INCLUSIVE_BIT = 1 << 15  # uint16 MSB


@dataclass(frozen=True)
class Metric:
    mid: int
    name: str
    unit: str
    side: str  # "host" | "device"

    @property
    def inclusive_mid(self) -> int:
        return self.mid | INCLUSIVE_BIT


class MetricRegistry:
    """Uniquing registry for metric descriptors (paper §4.1: environment merge)."""

    def __init__(self):
        self._by_name: dict[str, Metric] = {}
        self._by_id: dict[int, Metric] = {}

    def register(self, name: str, unit: str = "", side: str = "device") -> Metric:
        if name in self._by_name:
            return self._by_name[name]
        mid = len(self._by_name)
        if mid >= INCLUSIVE_BIT:
            raise ValueError("metric id space exhausted")
        m = Metric(mid, name, unit, side)
        self._by_name[name] = m
        self._by_id[mid] = m
        return m

    def merge(self, other: "MetricRegistry") -> dict[int, int]:
        """Merge ``other`` into self; return old-id -> new-id remapping."""
        remap = {}
        for name, m in other._by_name.items():
            remap[m.mid] = self.register(name, m.unit, m.side).mid
        return remap

    def __len__(self):
        return len(self._by_name)

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __getitem__(self, key):
        if isinstance(key, str):
            return self._by_name[key]
        return self._by_id[int(key) & ~INCLUSIVE_BIT]

    def name_of(self, mid: int) -> str:
        base = self._by_id[int(mid) & ~INCLUSIVE_BIT].name
        return base + ":I" if int(mid) & INCLUSIVE_BIT else base

    def to_json(self):
        return [
            {"mid": m.mid, "name": m.name, "unit": m.unit, "side": m.side}
            for m in self._by_name.values()
        ]

    @classmethod
    def from_json(cls, items) -> "MetricRegistry":
        reg = cls()
        for it in sorted(items, key=lambda d: d["mid"]):
            m = reg.register(it["name"], it.get("unit", ""), it.get("side", "device"))
            assert m.mid == it["mid"], "non-contiguous metric ids"
        return reg


# ---------------------------------------------------------------------------
# Standard metric sets for the in-job measurement subsystem.
# Host metrics mirror the paper's CPU metrics (REALTIME et al.); device
# metrics mirror its GPU metric sets (62-142 stall/throughput counters).
# ---------------------------------------------------------------------------

HOST_METRIC_NAMES = [
    "host.step_time",
    "host.data_wait",
    "host.dispatch",
    "host.checkpoint_io",
    "host.compile_time",
]

DEVICE_METRIC_NAMES = [
    "dev.flops",
    "dev.bytes_hbm",
    "dev.bytes_ici",
    "dev.time_compute",
    "dev.time_collective",
    "dev.occupancy",
    "dev.mem_peak",
]

FAMILY_METRIC_NAMES = {
    "attention": ["attn.qk_flops", "attn.av_flops", "attn.kv_bytes", "attn.softmax_time"],
    "moe": ["moe.tokens_routed", "moe.expert_load", "moe.drop_rate", "moe.a2a_bytes"],
    "ssm": ["ssm.state_bytes", "ssm.scan_time", "ssm.conv_time"],
    "dense": ["mlp.gemm_flops", "mlp.act_bytes"],
}


def default_registry(families=("attention", "dense")) -> MetricRegistry:
    reg = MetricRegistry()
    for n in HOST_METRIC_NAMES:
        reg.register(n, "s" if "time" in n or "wait" in n else "", side="host")
    for n in DEVICE_METRIC_NAMES:
        reg.register(n, side="device")
    for fam in families:
        for n in FAMILY_METRIC_NAMES[fam]:
            reg.register(n, side="device")
    return reg
