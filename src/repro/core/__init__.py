"""The paper's primary contribution: sparse formats + streaming aggregation.

Public surface:

* :mod:`repro.core.sparse`   — sparse measurement format (paper Fig. 1)
* :mod:`repro.core.cct`      — calling-context trees + preorder linearization
* :mod:`repro.core.pms`      — Profile-Major Sparse analysis DB
* :mod:`repro.core.cms`      — Context-Major Sparse analysis DB
* :mod:`repro.core.propagate`— exclusive->inclusive metric propagation
* :mod:`repro.core.stats`    — cross-profile summary statistics
* :mod:`repro.core.aggregate`— the streaming aggregation engine (paper §4)
* :mod:`repro.core.reduction`— process-level reduction trees (paper §4.4)
* :mod:`repro.core.dense_baseline` — the HPCToolkit-style dense baseline
"""
from repro.core.cct import ContextTree
from repro.core.metrics import INCLUSIVE_BIT, MetricRegistry, default_registry
from repro.core.sparse import MeasurementProfile, SparseMetrics, Trace

__all__ = [
    "ContextTree", "MetricRegistry", "default_registry", "INCLUSIVE_BIT",
    "MeasurementProfile", "SparseMetrics", "Trace",
]
