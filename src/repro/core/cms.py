"""Context-Major Sparse (CMS) analysis-results format (paper §3.2, §4.3.2).

Same sparse 3-tensor as PMS, ordered context-major: an array of context
offsets (exclusive scan over per-context plane sizes) followed by one CSR
plane per non-empty context::

    plane(ctx) = mids u16[m], mstart u64[m+1], prof u32[x], vals f64[x]

A (ctx, metric) "stripe" — the values of one metric for *all* profiles — is
a single contiguous read, which is the access pattern CMS exists to serve.

The builder follows paper §4.3.2: CMS is generated *from the completed PMS
file*; sizes are known, so offsets come from an exclusive scan, and workers
each assemble contiguous context groups and write at precomputed offsets
without coordination.  Both the faithful **heap-merge** per-group gather and
the TPU-shaped **vectorized transpose** (sort by (ctx, mid, profile)) are
implemented; they produce byte-identical planes.
"""
from __future__ import annotations

import heapq
import os
import struct

import numpy as np

from repro.utils import binio
from repro.core import loadbalance
from repro.core.pms import PMSReader

CMS_MAGIC = b"RCMS"
_HEADER = 24

# exact plane size for m non-empty metrics and x values (binio 1-D block = 13 + data)
def plane_nbytes(m: int, x: int) -> int:
    return 60 + 10 * m + 12 * x if x else 0


def _encode_plane(mids, mstart, prof, vals) -> bytes:
    return (binio.pack_array(mids) + binio.pack_array(mstart)
            + binio.pack_array(prof) + binio.pack_array(vals))


def empty_plane():
    """The canonical shape of a context with no data."""
    return (np.empty(0, np.uint16), np.zeros(1, np.uint64),
            np.empty(0, np.uint32), np.empty(0, np.float64))


def decode_plane(buf) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Wire format -> ``(mids, mstart, prof, vals)``; the single decoder
    shared by :class:`CMSReader` and the query engine's mmap path."""
    mids, off = binio.unpack_array(buf, 0)
    mstart, off = binio.unpack_array(buf, off)
    prof, off = binio.unpack_array(buf, off)
    vals, off = binio.unpack_array(buf, off)
    return mids, mstart, prof, vals


def stripe_from_plane(plane, mid: int) -> tuple[np.ndarray, np.ndarray]:
    """Slice one metric's (profiles, values) stripe out of a decoded plane."""
    mids, mstart, prof, vals = plane
    j = int(np.searchsorted(mids, mid))
    if j >= mids.size or mids[j] != mid:
        return np.empty(0, np.uint32), np.empty(0, np.float64)
    a, b = int(mstart[j]), int(mstart[j + 1])
    return prof[a:b], vals[a:b]


def stripe_from_buffer(buf, off: int, mid: int
                       ) -> tuple[np.ndarray, np.ndarray] | None:
    """Predicate-pushdown stripe read: decode ONE metric's (profiles,
    values) slice from an encoded plane at ``buf[off:]`` without
    materializing the other metrics.

    Only the tiny ``mids``/``mstart`` header arrays are parsed; the metric
    is binary-searched, and the matching sub-ranges of the ``prof`` and
    ``vals`` blocks are returned as zero-copy views over ``buf`` (the page
    cache, when ``buf`` is an mmap).  Returns ``None`` when the plane does
    not carry ``mid`` — the caller learns the predicate failed for the
    price of the header alone, never the plane.
    """
    mids, pos = binio.unpack_array(buf, off)
    mstart, pos = binio.unpack_array(buf, pos)
    j = int(np.searchsorted(mids, mid))
    if j >= mids.size or int(mids[j]) != int(mid):
        return None
    a, b = int(mstart[j]), int(mstart[j + 1])
    x = int(mstart[-1])
    # prof block (u32[x]) starts at pos; vals block (f64[x]) right after.
    # Each 1-D binio array block is a 13-byte header + payload (see
    # plane_nbytes); slice the [a, b) sub-range of each payload directly.
    # The dtype codes guard the hardcoded layout: a format drift must fail
    # loudly here, never mis-slice silently.
    if bytes(buf[pos:pos + 4]) != b"u32 ":
        raise ValueError("CMS plane layout drift: prof block is not u32")
    prof = np.frombuffer(buf, np.uint32, count=b - a, offset=pos + 13 + 4 * a)
    vals_block = pos + 13 + 4 * x
    if bytes(buf[vals_block:vals_block + 4]) != b"f64 ":
        raise ValueError("CMS plane layout drift: vals block is not f64")
    vals = np.frombuffer(buf, np.float64, count=b - a,
                         offset=vals_block + 13 + 8 * a)
    return prof, vals


# ---------------------------------------------------------------------------
# pass 1: size census over the PMS planes
# ---------------------------------------------------------------------------

def census(pms: PMSReader, n_ctx: int, compute: str = "cpu"
           ) -> tuple[np.ndarray, np.ndarray]:
    """Per-context (x_c, m_c): total values and distinct non-empty metrics.

    ``compute="device"`` routes the x_c histogram through the Pallas
    ``scatter_add`` kernel on real accelerators (counts are integers under
    the 2^24 f32-exactness guard, so the result is byte-identical); the
    helper returns None on plain hosts and the numpy path runs instead.
    """
    key_chunks: list[np.ndarray] = []
    uniq = np.empty(0, dtype=np.uint64)
    row_chunks: list[np.ndarray] = []
    for pid in range(pms.n_profiles):
        sm = pms.plane(pid)
        rows, mids, _ = sm.triplets()
        if rows.size == 0:
            continue
        row_chunks.append(rows.astype(np.int64))
        key_chunks.append((rows.astype(np.uint64) << np.uint64(16)) | mids.astype(np.uint64))
        if sum(k.size for k in key_chunks) > 1 << 22:
            uniq = np.unique(np.concatenate([uniq] + key_chunks))
            key_chunks = []
    if key_chunks:
        uniq = np.unique(np.concatenate([uniq] + key_chunks))
    rows_all = (np.concatenate(row_chunks) if row_chunks
                else np.empty(0, np.int64))
    x_c = None
    if compute == "device":
        from repro.kernels import batch
        x_c = batch.device_census_counts(rows_all, n_ctx)
    if x_c is None:
        x_c = np.bincount(rows_all, minlength=n_ctx).astype(np.int64)
    m_c = np.bincount((uniq >> np.uint64(16)).astype(np.int64), minlength=n_ctx)
    return x_c, m_c.astype(np.int64)


# ---------------------------------------------------------------------------
# pass 2: per-group gather (two strategies)
# ---------------------------------------------------------------------------

def _gather_group_vectorized(pms: PMSReader, lo: int, hi: int) -> dict[int, bytes]:
    """Transpose by sort: the TPU-shaped formulation (DESIGN.md §4)."""
    rs, ms, ps, vs = [], [], [], []
    for pid in range(pms.n_profiles):
        sm = pms.plane(pid)
        k0, k1 = np.searchsorted(sm.ctx, [lo, hi])
        if k0 == k1:
            continue
        i0, i1 = int(sm.start[k0]), int(sm.start[k1])
        rows = np.repeat(sm.ctx[k0:k1].astype(np.int64),
                         np.diff(sm.start[k0:k1 + 1].astype(np.int64)))
        rs.append(rows)
        ms.append(sm.mid[i0:i1].astype(np.int64))
        ps.append(np.full(i1 - i0, pid, dtype=np.int64))
        vs.append(sm.val[i0:i1])
    out: dict[int, bytes] = {}
    if not rs:
        return out
    rows = np.concatenate(rs); mids = np.concatenate(ms)
    pids = np.concatenate(ps); vals = np.concatenate(vs)
    order = np.lexsort((pids, mids, rows))
    rows, mids, pids, vals = rows[order], mids[order], pids[order], vals[order]
    ctx_bounds = np.flatnonzero(np.diff(rows, prepend=-1))
    ctx_ends = np.append(ctx_bounds[1:], rows.size)
    for b, e in zip(ctx_bounds, ctx_ends):
        out[int(rows[b])] = _encode_ctx_plane(mids[b:e], pids[b:e], vals[b:e])
    return out


def _encode_ctx_plane(mids, pids, vals) -> bytes:
    mb = np.flatnonzero(np.diff(mids, prepend=-1))
    umids = mids[mb].astype(np.uint16)
    mstart = np.append(mb, mids.size).astype(np.uint64)
    return _encode_plane(umids, mstart, pids.astype(np.uint32), vals.astype(np.float64))


def _gather_group_heap(pms: PMSReader, lo: int, hi: int) -> dict[int, bytes]:
    """Faithful heap-merge over profiles (paper §4.3.2)."""
    planes = []
    heap: list[tuple[int, int]] = []
    cursors = {}
    for pid in range(pms.n_profiles):
        sm = pms.plane(pid)
        k0, k1 = np.searchsorted(sm.ctx, [lo, hi])
        if k0 == k1:
            continue
        planes.append((pid, sm))
        cursors[pid] = (int(k0), int(k1), sm)
        heapq.heappush(heap, (int(sm.ctx[k0]), pid))
    out: dict[int, bytes] = {}
    acc_m: list[np.ndarray] = []
    acc_p: list[np.ndarray] = []
    acc_v: list[np.ndarray] = []
    cur_ctx = -1

    def flush():
        if cur_ctx < 0 or not acc_m:
            return
        mids = np.concatenate(acc_m); pids = np.concatenate(acc_p)
        vals = np.concatenate(acc_v)
        order = np.lexsort((pids, mids))
        out[cur_ctx] = _encode_ctx_plane(mids[order], pids[order], vals[order])

    while heap:
        ctx, pid = heapq.heappop(heap)
        if ctx != cur_ctx:
            flush()
            acc_m, acc_p, acc_v = [], [], []
            cur_ctx = ctx
        k0, k1, sm = cursors[pid]
        i0, i1 = int(sm.start[k0]), int(sm.start[k0 + 1])
        acc_m.append(sm.mid[i0:i1].astype(np.int64))
        acc_p.append(np.full(i1 - i0, pid, dtype=np.int64))
        acc_v.append(sm.val[i0:i1])
        k0 += 1
        cursors[pid] = (k0, k1, sm)
        if k0 < k1:
            heapq.heappush(heap, (int(sm.ctx[k0]), pid))
    flush()
    return out


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

def _cms_shard_worker(task) -> int:
    """Out-of-process CMS gather: one worker, one contiguous run of groups.

    Offsets are *not* shipped with the task — the parent has already
    written the header + offset table to the output file, so the worker
    re-reads them from there (the §4.3.2 property: once sizes are known,
    workers coordinate through precomputed offsets alone).  Returns the
    number of planes written (progress/debug only).
    """
    pms_path, out_path, strategy, groups = task
    pms = PMSReader(pms_path)
    f = open(str(out_path), "r+b")
    fd = f.fileno()
    head = os.pread(fd, _HEADER, 0)
    assert head[:4] == CMS_MAGIC, "CMS header not yet written"
    (n_ctx,) = struct.unpack_from("<Q", head, 8)
    raw = os.pread(fd, 8 * (int(n_ctx) + 1), _HEADER)
    offsets = np.frombuffer(raw, dtype=np.uint64)
    gather = (_gather_group_vectorized if strategy == "vectorized"
              else _gather_group_heap)
    written = 0
    for lo, hi in groups:
        planes = gather(pms, lo, hi)
        if not planes:
            continue
        buf = b"".join(planes[c] for c in sorted(planes))
        os.pwrite(fd, buf, int(offsets[min(planes)]))
        written += len(planes)
    f.close()
    pms.close()
    return written


def _shard_groups(groups, sizes: np.ndarray, n_workers: int):
    """Contiguous size-balanced split of groups across workers (static LB:
    dynamic assignment cannot cross address spaces without a server)."""
    gsz = np.array([int(np.sum(sizes[lo:hi])) for lo, hi in groups],
                   dtype=np.int64)
    csum = np.cumsum(gsz)
    total = int(csum[-1]) if gsz.size else 0
    shards: list[list[tuple[int, int]]] = [[] for _ in range(n_workers)]
    for g, grp in enumerate(groups):
        w = (min(int((csum[g] - 1) * n_workers // max(total, 1)),
                 n_workers - 1) if total else 0)
        shards[w].append(grp)
    return [s for s in shards if s]


def build_cms(pms_path, out_path, *, n_workers: int = 4, strategy: str = "vectorized",
              balance: str = "dynamic", group_target_bytes: int = 1 << 20,
              executor: str | None = None, timings: dict | None = None,
              compute: str = "cpu") -> int:
    """Generate the CMS file from a completed PMS file (paper §4.3.2).

    ``executor`` selects the worker substrate (default ``threads``):
    in-process backends run the gather workers through their own
    ``parallel_for`` (GLB dynamic assignment; ``serial`` drains every group
    inline), out-of-process backends (``processes``, ``ranks``) shard
    context groups statically across a worker pool.  Output bytes land at
    offsets fixed by the exclusive scan, so every substrate produces a
    byte-identical file.

    ``compute="device"`` runs the census histogram and the §4.3.2 offset
    scan through the Pallas kernels; both are exact integer ops, so the
    file bytes never depend on the backend.
    """
    pms = PMSReader(pms_path)
    n_ctx = len(pms.tree.parent) if pms.tree is not None else (
        int(max((int(pms.plane(p).ctx.max()) for p in range(pms.n_profiles)
                 if pms.plane(p).n_contexts), default=-1)) + 1)
    x_c, m_c = census(pms, n_ctx, compute=compute)
    sizes = np.where(x_c > 0, 60 + 10 * m_c + 12 * x_c, 0).astype(np.int64)
    offsets = np.zeros(n_ctx + 1, dtype=np.uint64)
    scanned = None
    if compute == "device":
        from repro.kernels import batch
        scanned = batch.device_offsets(sizes)  # int32 exclusive_scan kernel
    if scanned is not None:
        offsets[:] = scanned
    else:
        np.cumsum(sizes, out=offsets[1:])  # exclusive scan (paper §4.3.2)
    data_start = _HEADER + 8 * (n_ctx + 1)
    offsets += np.uint64(data_start)

    groups = loadbalance.make_groups(sizes, group_target_bytes)
    gather = _gather_group_vectorized if strategy == "vectorized" else _gather_group_heap

    from repro.runtime import get_executor
    ex_kwargs = {}
    if (compute == "device" and (executor or "threads") == "processes"
            and not os.environ.get("REPRO_MP_CONTEXT")):
        # deciding compute="device" initialized XLA in this process; forking
        # a threaded XLA parent can deadlock the children
        ex_kwargs["mp_context"] = "spawn"
    ex = get_executor(executor or "threads", n_workers, **ex_kwargs)

    f = open(str(out_path), "w+b")
    fd = f.fileno()
    f.write(CMS_MAGIC + struct.pack("<I", 1))
    f.write(struct.pack("<QQ", n_ctx, 0))
    f.write(offsets.tobytes())
    f.flush()  # workers use positional pwrites from here on

    if not ex.in_process:
        tasks = [(str(pms_path), str(out_path), strategy, shard)
                 for shard in _shard_groups(groups, sizes, n_workers)]
        with ex:
            for _ in ex.map_unordered(_cms_shard_worker, tasks):
                pass
    else:
        assigner = loadbalance.make_assigner(balance, groups, sizes, n_workers)

        def worker(w: int):
            # every worker opens its own reader: no shared file positions
            wpms = PMSReader(pms_path)
            while True:
                g = assigner.next_group(w)
                if g is None:
                    break
                lo, hi = g
                planes = gather(wpms, lo, hi)
                if not planes:
                    continue
                # group planes are contiguous: one buffer, one pwrite
                buf = b"".join(planes[c] for c in sorted(planes))
                os.pwrite(fd, buf, int(offsets[min(planes)]))
            wpms.close()

        with ex:
            ex.parallel_for(n_workers, worker)

    meta_off = int(offsets[-1])
    blob = binio.pack_json({"n_profiles": pms.n_profiles,
                            "registry": pms.meta.get("registry", [])})
    os.pwrite(fd, blob, meta_off)
    os.pwrite(fd, struct.pack("<Q", meta_off), 16)
    f.truncate(meta_off + len(blob))
    f.close()
    pms.close()
    return meta_off + len(blob)


class CMSReader:
    def __init__(self, path):
        self.path = str(path)
        self._f = open(self.path, "rb")
        self._fd = self._f.fileno()
        head = os.pread(self._fd, _HEADER, 0)
        assert head[:4] == CMS_MAGIC, "not a CMS file"
        self.n_ctx, self.meta_off = struct.unpack_from("<QQ", head, 8)
        self.n_ctx = int(self.n_ctx)
        raw = os.pread(self._fd, 8 * (self.n_ctx + 1), _HEADER)
        self.offsets = np.frombuffer(raw, dtype=np.uint64)
        blob = os.pread(self._fd, os.fstat(self._fd).st_size - int(self.meta_off),
                        int(self.meta_off))
        self.meta, _ = binio.unpack_json(blob, 0)

    def plane(self, ctx: int):
        """(mids, mstart, prof, vals) for one context; empty if no data."""
        lo, hi = int(self.offsets[ctx]), int(self.offsets[ctx + 1])
        if lo == hi:
            return empty_plane()
        return decode_plane(os.pread(self._fd, hi - lo, lo))

    def stripe(self, ctx: int, mid: int) -> tuple[np.ndarray, np.ndarray]:
        """All (profile, value) pairs of one metric for one context —
        the contiguous read CMS is designed for (paper §3.2)."""
        return stripe_from_plane(self.plane(ctx), mid)

    def query(self, ctx: int, mid: int, pid: int) -> float:
        prof, vals = self.stripe(ctx, mid)
        k = int(np.searchsorted(prof, pid))
        if k < prof.size and prof[k] == pid:
            return float(vals[k])
        return 0.0

    def nbytes(self) -> int:
        return os.fstat(self._fd).st_size

    def close(self):
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
