"""Fused phase-2 kernel: remap + redistribute + propagate + assemble in one
pass over a single sorted triplet buffer.

The legacy hot path re-materializes every profile three times::

    remap_contexts          -> from_triplets  (argsort #1)
    redistribute_placeholders -> from_triplets  (argsort #2)
    propagate_inclusive     -> dense (n_ctx x m) cumsum -> from_triplets (#3)

:func:`fused_transform` produces the **byte-identical** ``SparseMetrics``
with one stable argsort over the remapped triplet stream, inclusive values
computed by sparse segment sums over preorder intervals (``searchsorted`` on
``end``), and the final plane assembled by a linear two-stream merge — no
third sort, and no O(n_ctx x m) matrix unless density warrants it.

Bit-identity argument (the executor parity contract rides on this):

* duplicate (ctx, metric) keys are summed left-to-right in stable-sorted
  key order — exactly ``SparseMetrics.from_triplets``'s ``argsort(stable)``
  + ``add.at`` order.  Collapsing the legacy path's two combine passes into
  one is exact: the first pass sums each key's duplicates left-to-right and
  the second appends route contributions after the kept value, which is the
  same total order the single stable sort produces (non-placeholder entries
  precede route expansions in the concatenated stream);
* inclusive values are differences of prefix sums taken in preorder
  position order.  The legacy dense cumsum interleaves ``+0.0`` terms for
  empty positions; IEEE-754 guarantees ``x + 0.0 == x`` bit-for-bit unless
  ``x`` is ``-0.0``, and partial sums of stored (non-zero) values can
  produce ``+0.0`` but never ``-0.0`` — so the sparse prefix sum over only
  the non-empty positions is bitwise the same;
* the inclusive stream comes out ordered by (position, metric) — the same
  row-major order ``np.nonzero`` yields on the dense matrix — and inclusive
  keys (bit 15 set) never collide with exclusive keys, so the final legacy
  ``from_triplets`` is a pure merge of two sorted streams: reproduced here
  with two ``searchsorted`` scatters instead of an argsort.

The dense fallback (high observed density) runs the cumsum formulation on
the fused exclusive stream; both branches are bit-identical, so the cutoff
is a pure performance knob that cannot perturb output bytes.
"""
from __future__ import annotations

import numpy as np

from repro.core.metrics import INCLUSIVE_BIT
from repro.core.propagate import (expand_routes, propagate_inclusive,
                                  redistribute_placeholders)
from repro.core.sparse import (CTX_DTYPE, IDX_DTYPE, MID_DTYPE, VAL_DTYPE,
                               SparseMetrics)
from repro.core.stats import check_key_ranges

_KEY_SHIFT = 16

# use the dense (n_ctx x m) cumsum when the profile touches at least this
# fraction of the unified tree (the ancestor closure would approach n_ctx
# anyway), or when the matrix is trivially small
DENSE_FRACTION = 0.25
DENSE_SMALL = 4096


def _combine_sorted(keys: np.ndarray, vals: np.ndarray):
    """Stable-sort ``ctx << 16 | mid`` keys, sum duplicate keys left-to-right
    and drop zero sums — ``from_triplets``'s exact FP accumulation order.

    ``bincount(weights=...)`` accumulates strictly sequentially over the
    sorted stream — bit-identical to the ``np.add.at`` the legacy path uses
    (``np.add.reduceat`` is *not*: it sums segments pairwise).
    """
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    flags = np.diff(keys, prepend=-1) != 0
    ukeys = keys[flags]
    seg = np.cumsum(flags) - 1
    sums = np.bincount(seg, weights=vals, minlength=ukeys.size) if vals.size \
        else vals
    keep = sums != 0.0
    return ukeys[keep], sums[keep]


def _expand_route_keys(ph_keys: np.ndarray, ph_vals: np.ndarray, routes: dict):
    """Placeholder redistribution (paper §4.1.3) on packed keys.

    ``ph_keys`` are combined placeholder entries in ascending key order (the
    order the legacy path iterates them); each expands to its route's leaf
    contexts with the per-route normalized weights applied to the combined
    value — ``v * (w / w.sum())`` per element, the legacy arithmetic.
    """
    leaf_ctx, e_lens, norm_w = expand_routes(ph_keys >> _KEY_SHIFT, routes)
    r_mid = np.repeat(ph_keys & 0xFFFF, e_lens)
    r_vals = np.repeat(ph_vals, e_lens) * norm_w
    return leaf_ctx * (1 << _KEY_SHIFT) + r_mid, r_vals


def _inclusive_sparse(ectx, evals, col, m, prof_mids, parent, end):
    """Per-interval inclusive sums without densifying to (n_ctx x m).

    Candidates are the ancestor closure of the touched preorder positions —
    the only contexts whose interval ``[i, end[i])`` can contain a non-zero;
    per metric column, a prefix sum over the (position-sorted) non-zeros
    gives ``inclusive = csum[searchsorted(end)] - csum[searchsorted(i)]``.
    """
    n = end.size
    mark = np.zeros(n, dtype=bool)
    frontier = np.unique(ectx)
    mark[frontier] = True
    while frontier.size:
        p = parent[frontier]
        p = p[p >= 0]
        if p.size:
            p = np.unique(p)
            p = p[~mark[p]]
        if p.size == 0:
            break
        mark[p] = True
        frontier = p
    cand = np.flatnonzero(mark)

    # group entries by metric column; masking by boolean class preserves the
    # ascending-position order within each column (entries are ctx-sorted)
    grp = np.argsort(col, kind="stable")
    counts = np.bincount(col, minlength=m)
    cstart = np.concatenate([[0], np.cumsum(counts)])
    incl = np.empty((cand.size, m), dtype=np.float64)
    endc = end[cand]
    for c in range(m):
        seg = grp[cstart[c]:cstart[c + 1]]
        pc = ectx[seg]
        csum = np.concatenate([[0.0], np.cumsum(evals[seg])])
        lo = np.searchsorted(pc, cand, side="left")
        hi = np.searchsorted(pc, endc, side="left")
        incl[:, c] = csum[hi] - csum[lo]
    ir, ic = np.nonzero(incl)
    ikeys = cand[ir] * (1 << _KEY_SHIFT) + (prof_mids[ic] | INCLUSIVE_BIT)
    return ikeys, incl[ir, ic]


def _combine_sorted_device(keys: np.ndarray, vals: np.ndarray, device):
    """Device formulation of :func:`_combine_sorted`: the stable argsort
    stays on the CPU (it defines the dense ranks), the duplicate-key
    segment sums run on the ``segstats`` MXU kernel in f32 (exact for
    "exact"-class planes — see repro.kernels.batch's dtype contract)."""
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    flags = np.diff(keys, prepend=-1) != 0
    ukeys = keys[flags]
    seg = (np.cumsum(flags) - 1).astype(np.int32)
    sums = device.combine_sums(seg, vals.astype(np.float32))
    keep = sums != 0.0
    return ukeys[keep], sums[keep]


def _inclusive_device(ectx, evals, col, m, prof_mids, end, device):
    """Inclusive propagation on device: densify the combined exclusive
    stream to (n, m) f32 and batch it through the blockscan launch — the
    cumsum formulation of :func:`_inclusive_dense`, with f32 accumulation
    (byte-identical for "exact"-class planes, documented f32 rounding
    otherwise)."""
    n = end.size
    dense = np.zeros((n, m), dtype=np.float32)
    dense[ectx, col] = evals  # combined keys are unique: plain assignment
    incl = device.inclusive(dense)
    ir, ic = np.nonzero(incl)
    ikeys = ir.astype(np.int64) * (1 << _KEY_SHIFT) \
        + (prof_mids[ic] | INCLUSIVE_BIT)
    return ikeys, incl[ir, ic].astype(np.float64)


def _inclusive_dense(ectx, evals, col, m, prof_mids, end):
    """The legacy cumsum formulation, on the fused exclusive stream."""
    n = end.size
    dense = np.zeros((n, m), dtype=np.float64)
    dense[ectx, col] = evals
    ps = np.zeros((n + 1, m), dtype=np.float64)
    np.cumsum(dense, axis=0, out=ps[1:])
    incl = ps[end] - ps[np.arange(n)]
    ir, ic = np.nonzero(incl)
    ikeys = ir * (1 << _KEY_SHIFT) + (prof_mids[ic] | INCLUSIVE_BIT)
    return ikeys, incl[ir, ic]


def _assemble(keys: np.ndarray, vals: np.ndarray) -> SparseMetrics:
    """Key-sorted triplets -> the CSR plane, ``from_triplets``'s exact tail."""
    if keys.size == 0:
        return SparseMetrics.empty()
    ctx = keys >> _KEY_SHIFT
    bounds = np.flatnonzero(np.diff(ctx, prepend=-1))
    starts = np.concatenate([bounds, [ctx.size]]).astype(IDX_DTYPE)
    return SparseMetrics(
        ctx[bounds].astype(CTX_DTYPE), starts,
        (keys & 0xFFFF).astype(MID_DTYPE), vals.astype(VAL_DTYPE, copy=False),
    )


def transform_plane(
    metrics: SparseMetrics,
    remap: np.ndarray,
    routes: dict,
    parent: np.ndarray,
    end: np.ndarray,
    *,
    pipeline: str = "fused",
    keep_exclusive: bool = True,
    device=None,
) -> SparseMetrics:
    """The one phase-2 transform dispatch, shared by every executor path
    (in-process bodies, sharded workers, the ranks driver).

    The cross-executor byte-parity contract requires all paths to run the
    exact same transform for a given config — routing them through this
    helper makes divergence structurally impossible.  ``device`` (a
    :class:`repro.kernels.batch.DeviceAggregator` or None) selects the
    ``compute="device"`` backend; it requires the fused pipeline.
    """
    if pipeline == "fused":
        return fused_transform(metrics, remap, routes, parent, end,
                               keep_exclusive=keep_exclusive, device=device)
    if device is not None:
        raise ValueError("device compute requires pipeline='fused'")
    sm = metrics.remap_contexts(np.asarray(remap, dtype=np.int64))
    if routes:
        sm = redistribute_placeholders(sm, routes)
    return propagate_inclusive(sm, np.arange(end.size), end,
                               keep_exclusive=keep_exclusive)


def fused_transform(
    metrics: SparseMetrics,
    remap: np.ndarray,
    routes: dict,
    parent: np.ndarray,
    end: np.ndarray,
    *,
    keep_exclusive: bool = True,
    device=None,
) -> SparseMetrics:
    """Remap + redistribute + propagate + assemble one profile's plane.

    ``remap`` maps profile-local context ids to final *preorder* ids;
    ``routes`` maps placeholder preorder ids to ``(leaf_preorder_ids,
    weights)``; ``parent``/``end`` describe the unified tree in preorder
    space.  Returns bytes-identical output to the legacy chain
    ``propagate_inclusive(redistribute_placeholders(remap_contexts(...)))``.

    With ``device`` set (:class:`repro.kernels.batch.DeviceAggregator`),
    the combine's segment sums (large planes) and the inclusive propagation
    dispatch to the Pallas kernels under that module's per-plane dtype
    contract; everything else — and the decision *what* to offload — is a
    pure function of the plane, preserving cross-executor byte parity.
    """
    rows, mids, vals = metrics.triplets()
    if rows.size == 0:
        return SparseMetrics.empty()
    rows = np.asarray(remap, dtype=np.int64)[rows]
    # loud failure instead of silent key corruption: bit 15 of a raw mid is
    # INCLUSIVE_BIT, and huge remapped ctx ids would wrap the int64 keys
    check_key_ranges(rows, mids)
    keys = rows * (1 << _KEY_SHIFT) + mids

    if routes:
        ph_ids = np.fromiter(routes.keys(), dtype=np.int64)
        is_ph = np.isin(rows, ph_ids)
        # placeholder entries combine *before* weighting — (v1+v2)*w, the
        # legacy order — then expand; everything else stays a raw stream
        ph_keys, ph_vals = _combine_sorted(keys[is_ph], vals[is_ph])
        r_keys, r_vals = _expand_route_keys(ph_keys, ph_vals, routes)
        keys = np.concatenate([keys[~is_ph], r_keys])
        vals = np.concatenate([vals[~is_ph], r_vals])

    # the one big argsort: raw remapped stream (+ route expansions) -> the
    # combined exclusive plane, sorted by (ctx, mid) key
    if device is not None and device.wants_combine(keys.size):
        ekeys, evals = _combine_sorted_device(keys, vals, device)
    else:
        ekeys, evals = _combine_sorted(keys, vals)
    if ekeys.size == 0:
        return SparseMetrics.empty()

    ectx = (ekeys >> _KEY_SHIFT).astype(np.int64)
    emid = (ekeys & 0xFFFF).astype(np.int64)
    prof_mids = np.unique(emid)
    m = prof_mids.size
    col = np.searchsorted(prof_mids, emid)

    n = end.size
    if device is not None:
        ikeys, ivals = _inclusive_device(ectx, evals, col, m, prof_mids, end,
                                         device)
        return _assemble_final(ekeys, evals, ikeys, ivals, keep_exclusive)
    u = np.count_nonzero(np.diff(ectx, prepend=-1))  # distinct touched ctxs
    if n * m <= DENSE_SMALL or u >= max(1, int(n * DENSE_FRACTION)):
        ikeys, ivals = _inclusive_dense(ectx, evals, col, m, prof_mids, end)
    else:
        ikeys, ivals = _inclusive_sparse(ectx, evals, col, m, prof_mids,
                                         np.asarray(parent, np.int64), end)
    return _assemble_final(ekeys, evals, ikeys, ivals, keep_exclusive)


def _assemble_final(ekeys, evals, ikeys, ivals, keep_exclusive: bool
                    ) -> SparseMetrics:
    if not keep_exclusive:
        return _assemble(ikeys, ivals)

    # linear merge of the two key-sorted streams (no collisions: bit 15)
    na, nb = ekeys.size, ikeys.size
    fkeys = np.empty(na + nb, dtype=np.int64)
    fvals = np.empty(na + nb, dtype=np.float64)
    ia = np.arange(na) + np.searchsorted(ikeys, ekeys)
    ib = np.arange(nb) + np.searchsorted(ekeys, ikeys)
    fkeys[ia], fvals[ia] = ekeys, evals
    fkeys[ib], fvals[ib] = ikeys, ivals
    return _assemble(fkeys, fvals)
