"""Process-level parallelism: the two-phase reduction trees of paper §4.4.

Rank layout mirrors the paper: profiles are statically partitioned across
ranks; each rank streams its shard with the thread engine; communication
happens only at the two phase boundaries:

* **phase 1 reduction** — per-rank CCTs merge up a tree of branching
  factor *t* (one merge per available thread per round -> ``log_t n``
  rounds), then the final context ids broadcast back;
* **phase 2 reduction** — per-rank statistic accumulators merge up a
  second tree; per-rank PMS plane segments are stitched into the single
  output file by a prefix sum over segment sizes (the one-sided /
  server-thread offset allocation of §4.4, resolved here at assembly).

Implemented over ``multiprocessing`` (fork) as the MPI analog.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time

import numpy as np

from repro.core import cms as cms_mod
from repro.core.aggregate import (AggregationConfig, AnalysisResult,
                                  StreamingAggregator, _PhaseTimer, _renumber)
from repro.core.cct import ContextTree
from repro.core.pipeline import transform_plane
from repro.core.pms import PMSWriter
from repro.core.sparse import MeasurementProfile
from repro.core.stats import StatsAccumulator
from repro.core.traces import TraceDBWriter
# the generic reduction machinery is shared with the executor runtime
# (re-exported here for back-compat: tests and callers import it from us)
from repro.runtime.reduce import (TreeWithMaps as _TreeWithMaps,
                                  merge_tree_with_maps as _merge_trees,
                                  tree_reduce)

__all__ = ["aggregate_multiprocess", "tree_reduce"]


# ---------------------------------------------------------------------------
# worker bodies (module-level for multiprocessing)
# ---------------------------------------------------------------------------

def _phase1_worker(args):
    rank, paths, n_threads = args
    agg = StreamingAggregator(out_dir="/tmp", config=AggregationConfig(n_threads=n_threads))
    timer = _PhaseTimer()
    unified, remaps, routes, identities, trace_lens, registries = (
        agg.parse_contexts(paths, timer))
    return {
        "rank": rank,
        "tree": unified.to_arrays(),
        "remaps": remaps,
        "routes": routes,
        "identities": identities,
        "trace_lens": trace_lens,
        "registries": registries,
    }


def _phase2_worker(args):
    (rank, paths, remaps_final, routes_final, seg_path, trc_path,
     end_arr, parent_arr, keep_exclusive, pipeline) = args
    acc = StatsAccumulator()
    records = []
    trace_blobs = []
    with open(seg_path, "wb") as seg:
        off = 0
        for i, path in enumerate(paths):
            prof = MeasurementProfile.load(path)
            sm = transform_plane(prof.metrics, remaps_final[i],
                                 routes_final[i], parent_arr, end_arr,
                                 pipeline=pipeline,
                                 keep_exclusive=keep_exclusive)
            acc.update(sm)
            payload = sm.encode()
            seg.write(payload)
            records.append((i, off, len(payload), sm.n_contexts, sm.n_values))
            off += len(payload)
            if prof.trace.time.size:
                tr = prof.trace.remap_contexts(remaps_final[i])
                trace_blobs.append((i, tr.time, tr.ctx))
    return {"rank": rank, "records": records, "stats": acc.to_arrays(),
            "seg_path": seg_path, "traces": trace_blobs}


# ---------------------------------------------------------------------------
# the hybrid MPI+threads analog driver
# ---------------------------------------------------------------------------

def aggregate_multiprocess(
    profile_paths: list[str],
    out_dir: str,
    *,
    n_ranks: int = 2,
    threads_per_rank: int = 2,
    config: AggregationConfig | None = None,
) -> AnalysisResult:
    cfg = config or AggregationConfig()
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.perf_counter()
    n = len(profile_paths)
    shards = [profile_paths[r::n_ranks] for r in range(n_ranks)]
    # global profile id of shard[r][k] is r + k * n_ranks
    gids = [list(range(r, n, n_ranks)) for r in range(n_ranks)]

    ctx = mp.get_context("fork")
    with ctx.Pool(n_ranks) as pool:
        # ---- phase 1: parse + reduce CCTs (branching factor = threads) ----
        results1 = pool.map(_phase1_worker,
                            [(r, shards[r], threads_per_rank) for r in range(n_ranks)])
        items = [_TreeWithMaps(ContextTree.from_arrays(res["tree"]),
                               {res["rank"]: np.arange(len(res["tree"]["parent"]))})
                 for res in results1]
        merged, rounds = tree_reduce(items, _merge_trees, max(threads_per_rank, 2))
        pos, order, end = merged.tree.preorder()
        final_tree = _renumber(merged.tree, pos, order)
        n_ctx = len(final_tree)
        parent_pre = np.asarray(final_tree.parent, dtype=np.int64)

        # ---- broadcast final ids; compose per-profile remaps ----
        phase2_args = []
        trace_lens = np.zeros(n, dtype=np.int64)
        identities: list[dict | None] = [None] * n
        registry_json: list = []
        for res in results1:
            r = res["rank"]
            rank_map = pos[merged.maps[r]]  # local ctx -> final preorder id
            remaps_final = [rank_map[np.asarray(m, np.int64)] for m in res["remaps"]]
            routes_final = [
                {int(rank_map[ph]): (rank_map[np.asarray(t_, np.int64)], w)
                 for ph, (t_, w) in rt.items()}
                for rt in res["routes"]
            ]
            for k, g in enumerate(gids[r]):
                trace_lens[g] = res["trace_lens"][k]
                identities[g] = res["identities"][k]
            registry_json = registry_json or next((x for x in res["registries"] if x), [])
            seg_path = os.path.join(out_dir, f"seg{r}.bin")
            phase2_args.append((r, shards[r], remaps_final, routes_final,
                                seg_path, None, end, parent_pre,
                                cfg.keep_exclusive, cfg.pipeline))

        # ---- phase 2: stream metrics per rank ----
        results2 = pool.map(_phase2_worker, phase2_args)

    # ---- assemble final PMS: prefix sum over segment sizes = region alloc --
    pms_path = os.path.join(out_dir, "db.pms")
    pms = PMSWriter(pms_path, n)
    n_values = 0
    for res in sorted(results2, key=lambda d: d["rank"]):
        r = res["rank"]
        with open(res["seg_path"], "rb") as f:
            blob = f.read()
        region = pms.alloc(len(blob))
        pms.write_at(region, blob)
        for k, off, nb, nctx, nvals in res["records"]:
            g = gids[r][k]
            pms.record_plane(g, region + off, nb, nctx, nvals, identities[g])
            n_values += int(nvals)
        os.unlink(res["seg_path"])

    # ---- stats reduction tree ----
    accs = [StatsAccumulator.from_arrays(res["stats"]) for res in results2]
    root_acc, stat_rounds = tree_reduce(accs, lambda a, b: (a.merge(b), a)[1],
                                        max(threads_per_rank, 2))
    stats = root_acc.finalize() if root_acc is not None else {}
    pms_bytes = pms.finalize(tree=final_tree, registry_json=registry_json,
                             stats={k: np.asarray(v, np.float64)
                                    for k, v in stats.items()})

    # ---- traces ----
    trace_path = None
    if cfg.write_traces and trace_lens.sum() > 0:
        trace_path = os.path.join(out_dir, "db.trc")
        tw = TraceDBWriter(trace_path, [int(x) for x in trace_lens])
        from repro.core.sparse import Trace
        for res in results2:
            for k, ttime, tctx in res["traces"]:
                tw.write_trace(gids[res["rank"]][k], Trace(ttime, tctx))
        tw.close()

    # ---- CMS (root rank, GLB across its threads) ----
    cms_path = None
    cms_bytes = 0
    if cfg.write_cms:
        cms_path = os.path.join(out_dir, "db.cms")
        cms_bytes = cms_mod.build_cms(pms_path, cms_path,
                                      n_workers=cfg.cms_workers,
                                      strategy=cfg.cms_strategy,
                                      balance=cfg.cms_balance,
                                      group_target_bytes=cfg.group_target_bytes)

    sizes = {"pms": pms_bytes, "cms": cms_bytes}
    if trace_path:
        sizes["traces"] = os.path.getsize(trace_path)
    return AnalysisResult(
        pms_path=pms_path, cms_path=cms_path, trace_path=trace_path,
        n_profiles=n, n_contexts=n_ctx, n_values=n_values,
        timings={"total": time.perf_counter() - t_start,
                 "tree_rounds": rounds, "stat_rounds": stat_rounds},
        sizes=sizes,
    )
