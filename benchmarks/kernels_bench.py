"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle us/call.

On this CPU container, interpret-mode timings are NOT TPU performance —
they validate plumbing and give the oracle baseline; BlockSpecs target
TPU v5e.  Reported for completeness of the harness contract.

Standalone usage::

    PYTHONPATH=src python -m benchmarks.kernels_bench [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, reps=5):
    """Mean seconds/call over ``reps`` after one warmup (jit compile).

    ``jax.block_until_ready`` blocks on the whole returned pytree, so
    tuple-returning kernels (int8_quant) are timed to completion of every
    output, not just the first.
    """
    jax.block_until_ready(f(*args))  # warmup: one call, fully retired
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run(out=print, json_path: str | None = None):
    rng = np.random.default_rng(0)
    rows = []

    def bench(name, t, t_ref, sizes):
        rows.append({"name": name, "us": t * 1e6, "ref_us": t_ref * 1e6,
                     "sizes": sizes})
        tail = ";".join(f"{k}={v}" for k, v in sizes.items())
        out(f"kernels.{name},{t*1e6:.0f},ref_us={t_ref*1e6:.0f};{tail}")

    n, s = 1 << 14, 2048
    ids = jnp.asarray(np.sort(rng.integers(0, s, n)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    bench("segstats",
          _time(lambda a, b: ops.segstats(a, b, s), ids, vals),
          _time(lambda a, b: ref.segstats_ref(a, b, s), ids, vals),
          {"n": n, "s": s})

    x = jnp.asarray(rng.normal(size=(1 << 14, 4)).astype(np.float32))
    bench("blockscan", _time(ops.blockscan, x), _time(ref.blockscan_ref, x),
          {"n": x.shape[0]})

    uids = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    v2 = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    bench("scatter_add",
          _time(lambda a, b: ops.scatter_add(a, b, s), uids, v2),
          _time(lambda a, b: ref.scatter_add_ref(a, b, s), uids, v2),
          {"n": n, "s": s})

    g = jnp.asarray(rng.normal(size=1 << 15).astype(np.float32))
    bench("int8_quant",
          _time(ops.int8_quant, g),
          _time(lambda a: ref.int8_quant_ref(a, 2048), g),
          {"n": g.shape[0]})

    if json_path:
        report = {"backend": jax.default_backend(),
                  "interpret": jax.default_backend() != "tpu",
                  "kernels": rows}
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        out(f"kernels.report,0,json={json_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write a JSON report (e.g. BENCH_kernels.json)")
    args = ap.parse_args()
    run(json_path=args.out)


if __name__ == "__main__":
    main()
