"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle us/call.

On this CPU container, interpret-mode timings are NOT TPU performance —
they validate plumbing and give the oracle baseline; BlockSpecs target
TPU v5e.  Reported for completeness of the harness contract.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run(out=print):
    rng = np.random.default_rng(0)
    n, s = 1 << 14, 2048
    ids = jnp.asarray(np.sort(rng.integers(0, s, n)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    t = _time(lambda a, b: ops.segstats(a, b, s), ids, vals)
    t_ref = _time(lambda a, b: ref.segstats_ref(a, b, s), ids, vals)
    out(f"kernels.segstats,{t*1e6:.0f},ref_us={t_ref*1e6:.0f};n={n};s={s}")

    x = jnp.asarray(rng.normal(size=(1 << 14, 4)).astype(np.float32))
    t = _time(ops.blockscan, x)
    t_ref = _time(ref.blockscan_ref, x)
    out(f"kernels.blockscan,{t*1e6:.0f},ref_us={t_ref*1e6:.0f};n={x.shape[0]}")

    uids = jnp.asarray(rng.integers(0, s, n).astype(np.int32))
    v2 = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    t = _time(lambda a, b: ops.scatter_add(a, b, s), uids, v2)
    t_ref = _time(lambda a, b: ref.scatter_add_ref(a, b, s), uids, v2)
    out(f"kernels.scatter_add,{t*1e6:.0f},ref_us={t_ref*1e6:.0f};n={n};s={s}")

    g = jnp.asarray(rng.normal(size=1 << 15).astype(np.float32))
    t = _time(lambda a: ops.int8_quant(a)[0], g)
    t_ref = _time(lambda a: ref.int8_quant_ref(a, 2048)[0], g)
    out(f"kernels.int8_quant,{t*1e6:.0f},ref_us={t_ref*1e6:.0f};n={g.shape[0]}")


if __name__ == "__main__":
    run()
