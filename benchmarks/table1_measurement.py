"""Paper Table 1: sparse measurement format — size, densities, dense ratio.

For each paper row we synthesize a workload with the same (context
density, metric density, CPU/GPU metric mix) and compare the actual
on-disk bytes of the sparse measurement format against the equivalent
dense representation (n_ctx x n_metrics f64 per profile — the prior
HPCToolkit layout).  Paper reference ratios: 0.74x / 2.11x / 15.23x /
22.44x.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.workloads import TABLE1_WORKLOADS, generate
from repro.core.dense_baseline import dense_measurement_nbytes
from repro.core.sparse import MeasurementProfile

PAPER_RATIOS = {"AMG2013(1)": 0.74, "AMG2013(7)": 2.11,
                "PeleC(1+82)": 15.23, "Nyx(1+62)": 22.44}


def run(out=print):
    rows = []
    with tempfile.TemporaryDirectory() as td:
        for w in TABLE1_WORKLOADS:
            t0 = time.perf_counter()
            paths, n_ctx, n_metrics = generate(w, td)
            # whole-file comparison, as in the paper: both layouts carry
            # the same CCT/trace sections; only the metric block differs
            sparse_bytes = 0
            dense_bytes = 0
            ctx_d, met_d = [], []
            for p in paths:
                prof = MeasurementProfile.load(p)
                fsize = os.path.getsize(p)
                sparse_bytes += fsize
                dense_bytes += (fsize - prof.metrics.nbytes()
                                + dense_measurement_nbytes(len(prof.tree),
                                                           n_metrics))
                ctx_d.append(prof.metrics.n_contexts / len(prof.tree))
                met_d.append(prof.metrics.n_values
                             / max(prof.metrics.n_contexts * n_metrics, 1))
            dt = time.perf_counter() - t0
            ratio = dense_bytes / sparse_bytes
            rows.append((w.name, sparse_bytes, np.mean(ctx_d), np.mean(met_d),
                         ratio, PAPER_RATIOS[w.name], dt))
            out(f"table1.{w.name},{dt*1e6:.0f},size_MiB={sparse_bytes/2**20:.2f}"
                f";ctx_density={np.mean(ctx_d):.3f};met_density={np.mean(met_d):.3f}"
                f";dense_ratio={ratio:.2f};paper_ratio={PAPER_RATIOS[w.name]}")
    return rows


if __name__ == "__main__":
    run()
